//! Native execution of a bundle's graphs: the pure-Rust twin of the L2
//! JAX model (python/compile/model.py), used by the reference engine.
//!
//! Implements the decoder-only transformer with every *registered*
//! PEFT method (see [`crate::adapters`]: full / none / LoRA /
//! weight-centric OFT / input-centric OFTv2 / QLoRA / QOFT / BOFT /
//! HOFT), a hand-derived backward pass, and the Adam update — so
//! `train_step`, `eval_loss` and `logits_last` run without artifacts,
//! Python, or an accelerator. Method-specific math lives in each
//! adapter's own module; this file never matches on a method.
//!
//! The model itself lives in [`super::layers`] as an explicit layer
//! stack with a forward [`Tape`]; this module owns the bundle-level
//! contracts (graph I/O, parameter assembly — NF4/AWQ packs stay packed
//! as [`QuantWeight`]s for the fused kernels — and Adam) and
//! the microbatched training driver. Training decomposes every batch
//! into per-sequence microbatches whose gradient partials are combined
//! by a fixed-order pairwise tree reduction — so the summed gradients
//! (and the loss curve) are bitwise identical however many worker
//! threads execute the microbatches, and bitwise identical with or
//! without gradient checkpointing (recompute reruns the same
//! deterministic kernels on the same inputs).
//!
//! Every gradient formula here is locked against `jax.grad` of the L2
//! model by `python/tests/test_ref_backward.py`; the Rust code is a 1:1
//! transcription of that file's numpy mirror. The OFTv2 forward is
//! matrix-free: inputs are rotated block-by-block (quadratic work)
//! instead of merging `blockdiag(R) @ W` (cubic work) — see §3 of the
//! paper. The weight-centric baseline deliberately *does* materialize
//! the merge so timing comparisons remain honest.

use anyhow::{bail, ensure, Context, Result};

use super::layers::lmhead::{nll_dlogits, nll_stats, split_tokens};
use super::layers::{AdapterPlan, CheckpointPolicy, Ctx, Gradients, LayerStack, Tape};
use super::{lit_f32, scalar_f32, TrainOpts, Value};
use crate::adapters::{Adapter, DecodeApply};
use crate::coordinator::manifest::{
    adapted_linear_dims, Manifest, ModelDims, ParamSpec, QuantSpec,
};
use crate::quant::{AwqTensor, Nf4Tensor, QuantWeight};
use crate::tensor::Tensor;

// Stable public paths for the shared kernels (they moved into the
// layers tree with the layer/tape decomposition).
pub use super::layers::linear::{block_rotate_fast, build_cnp_blocks, cnp_backward};
pub use super::layers::Params;

/// Weight storage backend for quantized methods.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantKind {
    None,
    Nf4,
    Awq,
}

impl QuantKind {
    pub fn parse(s: &str) -> Result<QuantKind> {
        Ok(match s {
            "none" => QuantKind::None,
            "nf4" => QuantKind::Nf4,
            "awq" => QuantKind::Awq,
            other => bail!("unknown quant backend '{other}'; valid backends: none, nf4, awq"),
        })
    }
}

/// A bundle's native executor: dims + method + the manifest's input
/// contract, ready to run any of the three graphs.
pub struct RefBundle {
    pub dims: ModelDims,
    /// The registered PEFT method driving every adapted linear.
    pub adapter: &'static dyn Adapter,
    pub quant: QuantKind,
    stack: LayerStack,
    trainable: Vec<ParamSpec>,
    frozen: Vec<ParamSpec>,
    quantized: Vec<QuantSpec>,
    adam: (f64, f64, f64),
    /// Linears the scenario's targeting regexes deselected: they run
    /// the frozen base path everywhere (train, eval, decode, merge)
    /// and carry no adapter parameters or plan entries.
    skipped: std::collections::BTreeSet<String>,
}

impl RefBundle {
    pub fn from_manifest(man: &Manifest) -> Result<RefBundle> {
        let adapter = crate::adapters::get(&man.method)?;
        adapter.configure(&man.scenario)?;
        adapter.validate_dims(&man.model)?;
        let quant = QuantKind::parse(&man.quant)?;
        ensure!(
            man.model.d_model % man.model.n_heads == 0,
            "d_model {} not divisible by n_heads {}",
            man.model.d_model,
            man.model.n_heads
        );
        Ok(RefBundle {
            dims: man.model,
            adapter,
            quant,
            stack: LayerStack::build(&man.model),
            trainable: man.trainable.clone(),
            frozen: man.frozen.clone(),
            quantized: man.quantized.clone(),
            adam: man.adam,
            skipped: man.skipped.iter().cloned().collect(),
        })
    }

    pub fn n_trainable(&self) -> usize {
        self.trainable.len()
    }

    fn n_fixed(&self) -> usize {
        self.frozen.len() + self.quantized.len()
    }

    /// `step` is `Some` only on training passes — it gates module
    /// dropout (a pure function of seed/step/name, so bitwise identical
    /// across workers, ranks, recompute and resume); eval and decode
    /// paths pass `None` and never drop.
    fn ctx<'a>(&'a self, params: &'a Params, plan: &'a AdapterPlan, step: Option<u64>) -> Ctx<'a> {
        Ctx {
            params,
            dims: &self.dims,
            adapter: self.adapter,
            plan: Some(plan),
            skipped: Some(&self.skipped),
            step,
        }
    }

    /// Resolve the step's shared adapter state once, by asking the
    /// registered method for its per-linear plan entries (CNP blocks,
    /// merged weights, reflection directions — whatever the module
    /// defines). Every microbatch — on every worker — reads this one
    /// plan, so per-sequence decomposition does not re-pay per-step
    /// costs per sequence. Targeting-deselected linears have no
    /// adapter parameters, so no plan entries either.
    fn adapter_plan(&self, params: &Params) -> Result<AdapterPlan> {
        let mut plan = AdapterPlan::default();
        for (name, _, _) in adapted_linear_dims(&self.dims) {
            if self.skipped.contains(&name) {
                continue;
            }
            if let Some(entry) = self.adapter.plan_linear(&name, params, &self.dims)? {
                plan.insert(name, entry);
            }
        }
        Ok(plan)
    }

    /// (din, dout) of an adapted linear (mirrors manifest.linear_shape).
    fn linear_shape(&self, base: &str) -> Result<(usize, usize)> {
        let (d, f) = (self.dims.d_model, self.dims.d_ff);
        if base.ends_with(".mlp.up") {
            Ok((d, f))
        } else if base.ends_with(".mlp.down") {
            Ok((f, d))
        } else if base.contains(".attn.w") {
            Ok((d, d))
        } else {
            bail!("'{base}' is not an adapted linear weight")
        }
    }

    // -----------------------------------------------------------------
    // Parameter assembly
    // -----------------------------------------------------------------

    /// Name -> parameter map from graph inputs: trainables + frozen f32
    /// as dense tensors, NF4/AWQ packs as [`QuantWeight`]s — the packed
    /// codes go straight to the fused dequant-matmul kernels, so no f32
    /// copy of a quantized base weight is ever materialized (the memory
    /// property §4's QOFT claim rests on).
    fn assemble_params(&self, trainables: &[&Value], fixed: &[&Value]) -> Result<Params> {
        ensure!(
            trainables.len() == self.trainable.len(),
            "expected {} trainable inputs, got {}",
            self.trainable.len(),
            trainables.len()
        );
        ensure!(
            fixed.len() == self.n_fixed(),
            "expected {} fixed inputs, got {}",
            self.n_fixed(),
            fixed.len()
        );
        let mut map = std::collections::BTreeMap::new();
        let mut quant = std::collections::BTreeMap::new();
        for (spec, v) in self.trainable.iter().zip(trainables) {
            map.insert(spec.name.clone(), value_tensor(v, &spec.shape)?);
        }
        for (spec, v) in self.frozen.iter().zip(&fixed[..self.frozen.len()]) {
            map.insert(spec.name.clone(), value_tensor(v, &spec.shape)?);
        }
        if !self.quantized.is_empty() {
            let packs: Vec<(&QuantSpec, &Value)> = self
                .quantized
                .iter()
                .zip(&fixed[self.frozen.len()..])
                .map(|(s, v)| (s, *v))
                .collect();
            let mut seen: Vec<String> = Vec::new();
            for (spec, _) in &packs {
                if !seen.contains(&spec.base) {
                    seen.push(spec.base.clone());
                }
            }
            for base in seen {
                let w = self.quant_base(&base, &packs)?;
                quant.insert(base, w);
            }
        }
        Ok(Params { map, quant })
    }

    /// Assemble the packed [`QuantWeight`] of one base linear from its
    /// graph inputs. Every pack field is bounds-checked against
    /// `(din, dout)` (codes / absmax / scales lengths, non-empty
    /// offset), so an empty or truncated pack surfaces as an error
    /// naming the bad pack rather than an indexing panic.
    fn quant_base(&self, base: &str, packs: &[(&QuantSpec, &Value)]) -> Result<QuantWeight> {
        let (din, dout) = self.linear_shape(base)?;
        let field = |suffix: &str| -> Result<&Value> {
            packs
                .iter()
                .find(|(s, _)| s.base == base && s.name.ends_with(suffix))
                .map(|(_, v)| *v)
                .with_context(|| format!("missing pack '{base}.{suffix}'"))
        };
        match self.quant {
            QuantKind::Nf4 => {
                let offsets = field("nf4_offset")?.f32s()?;
                let offset = *offsets
                    .first()
                    .with_context(|| format!("pack '{base}.nf4_offset' is empty"))?;
                QuantWeight::nf4(Nf4Tensor {
                    codes: field("nf4_codes")?.u8s()?.to_vec(),
                    absmax_q: field("nf4_absmax_q")?.i8s()?.to_vec(),
                    absmax_s: field("nf4_absmax_s")?.f32s()?.to_vec(),
                    offset,
                    n: din * dout,
                    shape: vec![din, dout],
                })
                .with_context(|| format!("bad NF4 pack for '{base}' ({din}x{dout})"))
            }
            QuantKind::Awq => QuantWeight::awq(AwqTensor {
                codes: field("awq_codes")?.u8s()?.to_vec(),
                scales: field("awq_scales")?.f32s()?.to_vec(),
                eq: field("awq_eq")?.f32s()?.to_vec(),
                din,
                dout,
            })
            .with_context(|| format!("bad AWQ pack for '{base}' ({din}x{dout})")),
            QuantKind::None => bail!("bundle has quantized packs but quant backend 'none'"),
        }
    }

    // -----------------------------------------------------------------
    // Graph entry points (manifest I/O contracts)
    // -----------------------------------------------------------------

    /// `train_step(tr, m, v, fixed, tokens, mask, lr, t)` ->
    /// `new_tr + new_m + new_v + [loss]`, with default train options
    /// (no checkpointing, one worker).
    pub fn train_step(&self, inputs: &[&Value]) -> Result<Vec<Value>> {
        self.train_step_opts(inputs, TrainOpts::default())
    }

    /// As [`RefBundle::train_step`] with explicit gradient-checkpoint /
    /// worker options. The outputs are bitwise identical across every
    /// `opts` combination — see [`RefBundle::loss_and_grads_opts`].
    pub fn train_step_opts(&self, inputs: &[&Value], opts: TrainOpts) -> Result<Vec<Value>> {
        ensure!(
            opts.ranks <= 1,
            "ranks > 1 requires the sharded train step (train_step_sharded)"
        );
        let n = self.trainable.len();
        let want = 3 * n + self.n_fixed() + 4;
        ensure!(
            inputs.len() == want,
            "train_step expected {want} inputs, got {}",
            inputs.len()
        );
        let tr = &inputs[..n];
        let mom_m = &inputs[n..2 * n];
        let mom_v = &inputs[2 * n..3 * n];
        let fixed = &inputs[3 * n..3 * n + self.n_fixed()];
        let data = &inputs[3 * n + self.n_fixed()..];
        let tokens = data[0].i32s()?;
        let mask = data[1].f32s()?;
        let lr = scalar_f32(data[2])?;
        let t_step = scalar_f32(data[3])?;

        let params = self.assemble_params(tr, fixed)?;
        let (loss, mut grads) = self.loss_and_grads_stepped(
            &params,
            tokens,
            mask,
            opts,
            &super::LocalReducer,
            Some(t_step as u64),
        )?;

        let coef = AdamCoef::new(self.adam, lr, t_step);
        let mut new_p = Vec::with_capacity(n);
        let mut new_m = Vec::with_capacity(n);
        let mut new_v = Vec::with_capacity(n);
        for (i, spec) in self.trainable.iter().enumerate() {
            let g = grads
                .remove(&spec.name)
                .unwrap_or_else(|| Tensor::zeros(&spec.shape));
            ensure!(
                g.numel() == spec.numel(),
                "gradient for '{}' has {} elements, want {}",
                spec.name,
                g.numel(),
                spec.numel()
            );
            let p = tr[i].f32s()?;
            let m0 = mom_m[i].f32s()?;
            let v0 = mom_v[i].f32s()?;
            let numel = spec.numel();
            let mut pn = vec![0f32; numel];
            let mut mn = vec![0f32; numel];
            let mut vn = vec![0f32; numel];
            for j in 0..numel {
                (pn[j], mn[j], vn[j]) = coef.update(p[j], m0[j], v0[j], g.data[j]);
            }
            new_p.push(lit_f32(&spec.shape, &pn)?);
            new_m.push(lit_f32(&spec.shape, &mn)?);
            new_v.push(lit_f32(&spec.shape, &vn)?);
        }
        let mut out = new_p;
        out.extend(new_m);
        out.extend(new_v);
        out.push(super::lit_scalar_f32(loss));
        Ok(out)
    }

    /// ZeRO-1 sharded train step:
    /// `(tr, m_shard, v_shard, fixed, tokens, mask, lr, t)` ->
    /// `new_tr + [new_m_shard, new_v_shard] + [loss]`.
    ///
    /// Every rank holds the FULL trainables but only its contiguous
    /// [`super::shard_range`] slice of the flat concatenated Adam
    /// moments. Gradients are all-reduced over the same fixed-order
    /// pairwise tree as the single-process step (bitwise identical on
    /// every rank), each rank Adam-updates only its element window —
    /// the update is elementwise, so shard boundaries cannot change a
    /// bit — and the updated param shards are all-gathered back into
    /// full tensors. Net: `new_tr` and `loss` equal the unsharded step
    /// exactly, while per-rank moment residency shrinks ~1/ranks.
    pub fn train_step_sharded(
        &self,
        inputs: &[&Value],
        opts: TrainOpts,
        red: &dyn super::GradReducer,
    ) -> Result<Vec<Value>> {
        ensure!(
            opts.rank == red.rank() && opts.ranks == red.ranks(),
            "train opts say rank {} of {} but the reducer is rank {} of {}",
            opts.rank,
            opts.ranks,
            red.rank(),
            red.ranks()
        );
        let n = self.trainable.len();
        let want = n + 2 + self.n_fixed() + 4;
        ensure!(
            inputs.len() == want,
            "train_step_sharded expected {want} inputs, got {}",
            inputs.len()
        );
        let tr = &inputs[..n];
        let m_shard = inputs[n].f32s()?;
        let v_shard = inputs[n + 1].f32s()?;
        let fixed = &inputs[n + 2..n + 2 + self.n_fixed()];
        let data = &inputs[n + 2 + self.n_fixed()..];
        let tokens = data[0].i32s()?;
        let mask = data[1].f32s()?;
        let lr = scalar_f32(data[2])?;
        let t_step = scalar_f32(data[3])?;

        let total: usize = self.trainable.iter().map(|s| s.numel()).sum();
        ensure!(
            red.ranks() <= total,
            "more ranks ({}) than trainable elements ({total})",
            red.ranks()
        );
        let (lo, hi) = super::shard_range(total, red.rank(), red.ranks());
        ensure!(
            m_shard.len() == hi - lo && v_shard.len() == hi - lo,
            "moment shard has {} elements, rank {} of {} owns {}",
            m_shard.len(),
            red.rank(),
            red.ranks(),
            hi - lo
        );

        let params = self.assemble_params(tr, fixed)?;
        let (loss, mut grads) =
            self.loss_and_grads_stepped(&params, tokens, mask, opts, red, Some(t_step as u64))?;

        // This rank's [lo, hi) element window of params + grads, in
        // manifest order (missing grads are zeros, as in the full step).
        let mut p_win = Vec::with_capacity(hi - lo);
        let mut g_win = Vec::with_capacity(hi - lo);
        let mut off = 0usize;
        for (i, spec) in self.trainable.iter().enumerate() {
            let numel = spec.numel();
            let (a, b) = (off.max(lo), (off + numel).min(hi));
            if a < b {
                p_win.extend_from_slice(&tr[i].f32s()?[a - off..b - off]);
                match grads.remove(&spec.name) {
                    Some(g) => {
                        ensure!(
                            g.numel() == numel,
                            "gradient for '{}' has {} elements, want {numel}",
                            spec.name,
                            g.numel()
                        );
                        g_win.extend_from_slice(&g.data[a - off..b - off]);
                    }
                    None => g_win.resize(g_win.len() + (b - a), 0.0),
                }
            }
            off += numel;
        }

        let coef = AdamCoef::new(self.adam, lr, t_step);
        let mut pn = vec![0f32; hi - lo];
        let mut mn = vec![0f32; hi - lo];
        let mut vn = vec![0f32; hi - lo];
        for j in 0..hi - lo {
            (pn[j], mn[j], vn[j]) = coef.update(p_win[j], m_shard[j], v_shard[j], g_win[j]);
        }

        // All-gather updated element shards back into full params.
        let shards = red.all_gather_f32(&pn)?;
        ensure!(
            shards.len() == red.ranks(),
            "all-gather returned {} shards for {} ranks",
            shards.len(),
            red.ranks()
        );
        let mut flat = Vec::with_capacity(total);
        for (r, s) in shards.iter().enumerate() {
            let (a, b) = super::shard_range(total, r, red.ranks());
            ensure!(
                s.len() == b - a,
                "rank {r} gathered {} param elements, expected {}",
                s.len(),
                b - a
            );
            flat.extend_from_slice(s);
        }

        let mut out = Vec::with_capacity(n + 3);
        let mut off = 0usize;
        for spec in &self.trainable {
            let numel = spec.numel();
            out.push(lit_f32(&spec.shape, &flat[off..off + numel])?);
            off += numel;
        }
        out.push(lit_f32(&[hi - lo], &mn)?);
        out.push(lit_f32(&[hi - lo], &vn)?);
        out.push(super::lit_scalar_f32(loss));
        Ok(out)
    }

    /// `eval_loss(tr, fixed, tokens, mask)` -> `(sum_nll, token_count)`.
    pub fn eval_loss(&self, inputs: &[&Value]) -> Result<Vec<Value>> {
        let n = self.trainable.len();
        let want = n + self.n_fixed() + 2;
        ensure!(
            inputs.len() == want,
            "eval_loss expected {want} inputs, got {}",
            inputs.len()
        );
        let tr = &inputs[..n];
        let fixed = &inputs[n..n + self.n_fixed()];
        let tokens = inputs[n + self.n_fixed()].i32s()?;
        let mask = inputs[n + self.n_fixed() + 1].f32s()?;
        let params = self.assemble_params(tr, fixed)?;

        let (bsz, t) = (self.dims.batch, self.dims.seq_len);
        ensure!(tokens.len() == bsz * (t + 1), "tokens shape mismatch");
        ensure!(mask.len() == bsz * t, "mask shape mismatch");
        self.validate_token_ids(tokens)?;
        let (inputs_ids, targets) = split_tokens(tokens, bsz, t);
        let fwd = self.forward(&params, &inputs_ids, bsz)?;
        let (sum_nll, count, _) = nll_stats(&fwd.logits, &targets, mask);
        Ok(vec![
            super::lit_scalar_f32(sum_nll),
            super::lit_scalar_f32(count),
        ])
    }

    /// `logits_last(tr, fixed, tokens (1, T) i32, cur_len i32)` ->
    /// `(logits (V,),)`.
    pub fn logits_last(&self, inputs: &[&Value]) -> Result<Vec<Value>> {
        let n = self.trainable.len();
        let want = n + self.n_fixed() + 2;
        ensure!(
            inputs.len() == want,
            "logits_last expected {want} inputs, got {}",
            inputs.len()
        );
        let tr = &inputs[..n];
        let fixed = &inputs[n..n + self.n_fixed()];
        let tokens = inputs[n + self.n_fixed()].i32s()?;
        let cur = inputs[n + self.n_fixed() + 1].i32s()?[0];
        let params = self.assemble_params(tr, fixed)?;

        let t = self.dims.seq_len;
        let v = self.dims.vocab;
        ensure!(tokens.len() == t, "logits_last tokens must be (1, {t})");
        let fwd = self.forward(&params, tokens, 1)?;
        let idx = (cur - 1).clamp(0, t as i32 - 1) as usize;
        let row = fwd.logits.data[idx * v..(idx + 1) * v].to_vec();
        Ok(vec![lit_f32(&[v], &row)?])
    }

    /// Reject out-of-vocab (or negative) ids up front: targets index
    /// the log-prob rows directly, so a bad id must surface as an error
    /// rather than an out-of-bounds panic.
    fn validate_token_ids(&self, tokens: &[i32]) -> Result<()> {
        let vocab = self.dims.vocab;
        for &id in tokens {
            ensure!(
                id >= 0 && (id as usize) < vocab,
                "token id {id} out of vocab {vocab}"
            );
        }
        Ok(())
    }

    // -----------------------------------------------------------------
    // Forward / backward (delegating to the layer stack)
    // -----------------------------------------------------------------

    /// Whole-batch forward pass with a full tape (eval / logits paths
    /// — no step, so module dropout never fires here).
    fn forward(&self, params: &Params, input_ids: &[i32], bsz: usize) -> Result<Tape> {
        let plan = self.adapter_plan(params)?;
        let ctx = self.ctx(params, &plan, None);
        self.stack
            .forward(&ctx, input_ids, bsz, CheckpointPolicy::None)
    }

    /// Mean masked NLL and gradients for every trainable parameter
    /// (default options: no checkpointing, one worker).
    pub fn loss_and_grads(
        &self,
        params: &Params,
        tokens: &[i32],
        mask: &[f32],
    ) -> Result<(f32, Gradients)> {
        self.loss_and_grads_opts(params, tokens, mask, TrainOpts::default())
    }

    /// Mean masked NLL + gradients, computed as per-sequence
    /// microbatches combined by a fixed-order pairwise tree reduction.
    ///
    /// The decomposition is *worker-independent*: each sequence of the
    /// batch is one microbatch, every microbatch's forward/backward
    /// runs the same deterministic kernels whatever thread executes it,
    /// and the reduction tree is ordered by microbatch index — so the
    /// loss and every gradient are bitwise identical for 1, 2, or N
    /// workers, with or without gradient checkpointing.
    pub fn loss_and_grads_opts(
        &self,
        params: &Params,
        tokens: &[i32],
        mask: &[f32],
        opts: TrainOpts,
    ) -> Result<(f32, Gradients)> {
        self.loss_and_grads_reduced(params, tokens, mask, opts, &super::LocalReducer)
    }

    /// As [`RefBundle::loss_and_grads_opts`], but with the microbatch
    /// leaves split across a rank group: this rank forwards/backwards
    /// only its contiguous leaf chunk (`shard_range` over sequence
    /// index), then all ranks all-reduce through `red` — the SAME
    /// fixed-order pairwise tree, with cross-rank pairs exchanged over
    /// the reducer instead of combined locally. With the in-process
    /// [`super::LocalReducer`] this is exactly the single-process path.
    pub fn loss_and_grads_reduced(
        &self,
        params: &Params,
        tokens: &[i32],
        mask: &[f32],
        opts: TrainOpts,
        red: &dyn super::GradReducer,
    ) -> Result<(f32, Gradients)> {
        self.loss_and_grads_stepped(params, tokens, mask, opts, red, None)
    }

    /// The internal stepped variant behind every loss/grad entry point:
    /// train steps pass `Some(t)` (enabling module dropout at that
    /// optimizer step), direct/eval callers pass `None`.
    fn loss_and_grads_stepped(
        &self,
        params: &Params,
        tokens: &[i32],
        mask: &[f32],
        opts: TrainOpts,
        red: &dyn super::GradReducer,
        step: Option<u64>,
    ) -> Result<(f32, Gradients)> {
        let (bsz, t) = (self.dims.batch, self.dims.seq_len);
        ensure!(tokens.len() == bsz * (t + 1), "tokens shape mismatch");
        ensure!(mask.len() == bsz * t, "mask shape mismatch");
        self.validate_token_ids(tokens)?;

        // The NLL normalizer is global across microbatches. Mask
        // entries are 0/1, so this sum is an exact small integer in f32
        // regardless of summation order.
        let count = mask.iter().sum::<f32>().max(1.0);
        let inv_count = 1.0 / count;

        // Per-step adapter state (CNP blocks, merged weights) resolved
        // once, shared read-only by every microbatch and worker.
        let plan = self.adapter_plan(params)?;
        let (lo, hi) = super::shard_range(bsz, red.rank(), red.ranks());
        let parts = run_sharded(hi - lo, opts.workers, |j| {
            self.seq_microbatch(
                params,
                &plan,
                tokens,
                mask,
                lo + j,
                inv_count,
                opts.checkpoint,
                step,
            )
        })?;

        // Fixed-order pairwise tree over global microbatch index.
        let (sum_nll, grads) = red.reduce(bsz, parts)?;
        Ok((sum_nll / count, grads))
    }

    /// Forward + backward of one sequence: returns its (sum_nll,
    /// gradient partial).
    #[allow(clippy::too_many_arguments)]
    fn seq_microbatch(
        &self,
        params: &Params,
        plan: &AdapterPlan,
        tokens: &[i32],
        mask: &[f32],
        seq: usize,
        inv_count: f32,
        policy: CheckpointPolicy,
        step: Option<u64>,
    ) -> Result<(f32, Gradients)> {
        let t = self.dims.seq_len;
        let row = &tokens[seq * (t + 1)..(seq + 1) * (t + 1)];
        let (input_ids, targets) = split_tokens(row, 1, t);
        let mask_row = &mask[seq * t..(seq + 1) * t];
        let ctx = self.ctx(params, plan, step);
        let tape = self.stack.forward(&ctx, &input_ids, 1, policy)?;
        let (sum_nll, _, logp) = nll_stats(&tape.logits, &targets, mask_row);
        let dlogits = nll_dlogits(&logp, &targets, mask_row, inv_count);
        let grads = self.stack.backward(&ctx, &tape, &dlogits)?;
        Ok((sum_nll, grads))
    }
}

/// The per-element Adam update — the ONE set of float expressions both
/// the full and the ZeRO-1 sharded step execute, so element j's result
/// is bitwise identical wherever (and on whichever rank) it computes.
struct AdamCoef {
    b1: f32,
    b2: f32,
    eps: f32,
    bc1: f32,
    bc2: f32,
    lr: f32,
}

impl AdamCoef {
    fn new(adam: (f64, f64, f64), lr: f32, t_step: f32) -> AdamCoef {
        let (b1, b2, eps) = (adam.0 as f32, adam.1 as f32, adam.2 as f32);
        AdamCoef {
            b1,
            b2,
            eps,
            bc1: 1.0 - b1.powf(t_step),
            bc2: 1.0 - b2.powf(t_step),
            lr,
        }
    }

    /// `(p, m, v, g) -> (p', m', v')`.
    #[inline]
    fn update(&self, p: f32, m0: f32, v0: f32, g: f32) -> (f32, f32, f32) {
        let mm = self.b1 * m0 + (1.0 - self.b1) * g;
        let vv = self.b2 * v0 + (1.0 - self.b2) * g * g;
        let mhat = mm / self.bc1;
        let vhat = vv / self.bc2;
        (p - self.lr * mhat / (vhat.sqrt() + self.eps), mm, vv)
    }
}

/// Fixed-order pairwise tree reduction: combine(parts[0], parts[1]),
/// combine(parts[2], parts[3]), ... repeatedly. The tree shape depends
/// only on `parts.len()`, never on which threads produced the parts.
/// `comms::RankGroup::tree_all_reduce` walks this exact schedule with
/// the leaves distributed over ranks — keep the two in lockstep.
pub(crate) fn tree_reduce<T>(mut parts: Vec<T>, combine: impl Fn(T, T) -> T) -> Option<T> {
    while parts.len() > 1 {
        let mut next = Vec::with_capacity(parts.len().div_ceil(2));
        let mut it = parts.into_iter();
        while let Some(a) = it.next() {
            match it.next() {
                Some(b) => next.push(combine(a, b)),
                None => next.push(a),
            }
        }
        parts = next;
    }
    parts.pop()
}

/// Run `f(0..n)` across `workers` scoped threads (contiguous shards),
/// returning the results in index order. Results are position-indexed,
/// so the output — and everything downstream — is independent of the
/// worker count; workers only decide who computes what. Worker threads
/// cap the tensor kernels' nested parallelism at one thread each: the
/// coarse per-microbatch parallelism replaces the per-matmul row
/// threading (which per-row determinism makes bitwise irrelevant).
fn run_sharded<T: Send>(
    n: usize,
    workers: usize,
    f: impl Fn(usize) -> Result<T> + Sync,
) -> Result<Vec<T>> {
    let workers = workers.clamp(1, n.max(1));
    if workers <= 1 {
        return (0..n).map(f).collect();
    }
    let mut slots: Vec<Option<Result<T>>> = (0..n).map(|_| None).collect();
    let per = n.div_ceil(workers);
    std::thread::scope(|s| {
        for (w, chunk) in slots.chunks_mut(per).enumerate() {
            let f = &f;
            s.spawn(move || {
                crate::tensor::set_thread_cap(1);
                for (j, slot) in chunk.iter_mut().enumerate() {
                    *slot = Some(f(w * per + j));
                }
            });
        }
    });
    slots
        .into_iter()
        .map(|o| o.expect("worker missed a microbatch"))
        .collect()
}

// ---------------------------------------------------------------------------
// Incremental (KV-cached) decoding
// ---------------------------------------------------------------------------

use super::layers::mlp::gelu_fwd;
use super::layers::rmsnorm::rmsnorm_fwd;

/// One transformer layer with every adapted linear resolved at build
/// time into its method's [`DecodeApply`] object: decode steps pay
/// only the per-token apply, never CNP block construction — and
/// quantized bases stay packed, each token's gemv decoding the codes
/// group-by-group through the fused kernels. That re-decode per token
/// is the deliberate 4-bit inference trade (packed residency for
/// unpack work, as in bitsandbytes/AWQ inference kernels); the serving
/// bench measures the resulting per-token cost for a QOFT adapter.
struct DecLayer {
    attn_norm: Vec<f32>,
    wq: Box<dyn DecodeApply>,
    wk: Box<dyn DecodeApply>,
    wv: Box<dyn DecodeApply>,
    wo: Box<dyn DecodeApply>,
    mlp_norm: Vec<f32>,
    up: Box<dyn DecodeApply>,
    down: Box<dyn DecodeApply>,
}

/// Row-level access to one sequence's KV storage during incremental
/// decode. Two implementations exist: the contiguous per-session
/// [`KvCache`] (the original path, kept as the bitwise oracle the way
/// `dequantize()` backs `tensor::fused`) and the paged [`PagedKv`]
/// view over a shared [`KvBlockPool`]. `forward_step` is generic over
/// this trait, so both storage layouts run the *same* attention
/// arithmetic in the same order — token streams match bitwise.
pub trait KvStore {
    /// Store the freshly computed K/V rows of layer `li` at `pos`
    /// (each `d_model` wide). `pos` grows by one per step; the backing
    /// row must already be allocated.
    fn write_row(&mut self, li: usize, pos: usize, k: &[f32], v: &[f32]);
    /// K row of layer `li` at position `t` (`t` ≤ the last written pos).
    fn k_row(&self, li: usize, t: usize) -> &[f32];
    /// V row of layer `li` at position `t`.
    fn v_row(&self, li: usize, t: usize) -> &[f32];
}

/// Per-sequence contiguous KV cache: one (seq_len, d_model) key and
/// value plane per layer, filled left to right — allocated at full
/// seq_len up front, which is exactly the per-session growth the
/// paged pool eliminates.
pub struct KvCache {
    /// Interleaved per layer: k then v, each seq_len * d_model.
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    d_model: usize,
    len: usize,
}

impl KvCache {
    pub fn position(&self) -> usize {
        self.len
    }
}

impl KvStore for KvCache {
    fn write_row(&mut self, li: usize, pos: usize, k: &[f32], v: &[f32]) {
        let d = self.d_model;
        self.k[li][pos * d..(pos + 1) * d].copy_from_slice(k);
        self.v[li][pos * d..(pos + 1) * d].copy_from_slice(v);
    }

    fn k_row(&self, li: usize, t: usize) -> &[f32] {
        let d = self.d_model;
        &self.k[li][t * d..(t + 1) * d]
    }

    fn v_row(&self, li: usize, t: usize) -> &[f32] {
        let d = self.d_model;
        &self.v[li][t * d..(t + 1) * d]
    }
}

// ---------------------------------------------------------------------------
// Paged KV: fixed-size blocks from a shared free-list pool
// ---------------------------------------------------------------------------

/// A shared handle to one [`KvBlockPool`] — every paged decode session
/// of every adapter over one base draws blocks from the same pool.
pub type SharedKvPool = std::sync::Arc<std::sync::Mutex<KvBlockPool>>;

/// Occupancy counters of a [`KvBlockPool`] (serving metrics + the
/// bounded-block-count assertions in tests).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct KvPoolStats {
    pub block_tokens: usize,
    /// Hard capacity in blocks; `alloc` fails beyond it.
    pub capacity_blocks: usize,
    /// Blocks ever materialized in the slab (high-water mark of real
    /// memory; recycled blocks never grow it).
    pub slab_blocks: usize,
    pub in_use: usize,
    pub peak_in_use: usize,
    /// Total `alloc` calls served (block churn across sessions).
    pub total_allocs: u64,
}

impl KvPoolStats {
    /// Bytes of KV slab actually materialized.
    pub fn slab_bytes(&self, n_layers: usize, d_model: usize) -> u64 {
        (self.slab_blocks * n_layers * 2 * self.block_tokens * d_model * 4) as u64
    }
}

/// Fixed-size KV block allocator shared across all decode sessions:
/// each block holds `block_tokens` positions of K and V rows for every
/// layer. Blocks are handed out from a free list and recycled when a
/// session ends, so total KV memory is bounded by `max_blocks` however
/// many sequences come and go — no per-session contiguous seq_len
/// planes. Reused blocks are *not* zeroed: a session only ever reads
/// positions it has itself written.
pub struct KvBlockPool {
    n_layers: usize,
    d_model: usize,
    block_tokens: usize,
    max_blocks: usize,
    /// Block storage, grown on demand up to `max_blocks` blocks.
    slab: Vec<f32>,
    /// Recycled block ids, ready for reuse.
    free: Vec<u32>,
    /// Per-block allocation state (indexed by block id); guards the
    /// free list against double releases.
    allocated: Vec<bool>,
    in_use: usize,
    peak_in_use: usize,
    total_allocs: u64,
}

impl KvBlockPool {
    pub fn new(
        n_layers: usize,
        d_model: usize,
        block_tokens: usize,
        max_blocks: usize,
    ) -> Result<KvBlockPool> {
        ensure!(n_layers > 0 && d_model > 0, "degenerate KV shape");
        ensure!(block_tokens > 0, "KV block_tokens must be positive");
        ensure!(max_blocks > 0, "KV pool needs at least one block");
        Ok(KvBlockPool {
            n_layers,
            d_model,
            block_tokens,
            max_blocks,
            slab: Vec::new(),
            free: Vec::new(),
            allocated: Vec::new(),
            in_use: 0,
            peak_in_use: 0,
            total_allocs: 0,
        })
    }

    /// A pool behind the shared handle decode sessions take.
    pub fn shared(
        n_layers: usize,
        d_model: usize,
        block_tokens: usize,
        max_blocks: usize,
    ) -> Result<SharedKvPool> {
        Ok(std::sync::Arc::new(std::sync::Mutex::new(KvBlockPool::new(
            n_layers,
            d_model,
            block_tokens,
            max_blocks,
        )?)))
    }

    /// f32 elements per block.
    fn block_floats(&self) -> usize {
        self.n_layers * 2 * self.block_tokens * self.d_model
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// Blocks needed to hold `tokens` positions.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    /// Blocks still allocatable right now.
    pub fn available(&self) -> usize {
        self.max_blocks - self.in_use
    }

    /// Whether this pool's row shape matches `dims` (a session of a
    /// mismatched model must not attach).
    pub fn matches(&self, dims: &ModelDims) -> bool {
        self.n_layers == dims.n_layers && self.d_model == dims.d_model
    }

    /// Take one block (recycled if possible, fresh slab growth
    /// otherwise). Fails when the pool is at capacity — admission
    /// control is expected to prevent that (see `serve::alloc`).
    pub fn alloc(&mut self) -> Result<u32> {
        let id = match self.free.pop() {
            Some(id) => id,
            None => {
                let next = self.slab.len() / self.block_floats().max(1);
                ensure!(
                    next < self.max_blocks,
                    "KV block pool exhausted: {} blocks in use of {} \
                     (block_tokens={})",
                    self.in_use,
                    self.max_blocks,
                    self.block_tokens
                );
                self.slab.resize(self.slab.len() + self.block_floats(), 0.0);
                self.allocated.push(false);
                next as u32
            }
        };
        self.allocated[id as usize] = true;
        self.in_use += 1;
        self.peak_in_use = self.peak_in_use.max(self.in_use);
        self.total_allocs += 1;
        Ok(id)
    }

    /// Return a block to the free list. Releasing a block that is not
    /// currently allocated is a caller accounting bug: it trips a debug
    /// assert, and in release builds is ignored rather than pushing the
    /// id onto the free list twice (which would hand the same KV rows
    /// to two sessions and silently corrupt both).
    pub fn release(&mut self, id: u32) {
        let live = self.allocated.get(id as usize).copied().unwrap_or(false);
        debug_assert!(live, "released KV block {id} that is not allocated");
        if !live {
            return;
        }
        self.allocated[id as usize] = false;
        self.free.push(id);
        self.in_use -= 1;
    }

    /// Raise the block capacity (never shrinks, so outstanding blocks
    /// and reservations stay valid). Used when an adapter attached
    /// after pool creation has a longer seq_len than the pool was
    /// originally sized for (see `serve::alloc::KvBudget`).
    pub fn grow_capacity(&mut self, max_blocks: usize) {
        self.max_blocks = self.max_blocks.max(max_blocks);
    }

    pub fn stats(&self) -> KvPoolStats {
        KvPoolStats {
            block_tokens: self.block_tokens,
            capacity_blocks: self.max_blocks,
            slab_blocks: self.slab.len() / self.block_floats().max(1),
            in_use: self.in_use,
            peak_in_use: self.peak_in_use,
            total_allocs: self.total_allocs,
        }
    }

    /// (k-rows offset, v-rows offset) of layer `li` in block `block`.
    fn layer_base(&self, block: u32, li: usize) -> (usize, usize) {
        let base = block as usize * self.block_floats()
            + li * 2 * self.block_tokens * self.d_model;
        (base, base + self.block_tokens * self.d_model)
    }
}

/// One sequence's view over a [`KvBlockPool`]: its block table plus a
/// mutable borrow of the pool slab for the duration of one step.
pub struct PagedKv<'a> {
    pool: &'a mut KvBlockPool,
    blocks: &'a [u32],
}

impl<'a> PagedKv<'a> {
    /// `blocks` must cover every position touched this step (the
    /// session allocates the next block *before* stepping into it).
    pub fn new(pool: &'a mut KvBlockPool, blocks: &'a [u32]) -> PagedKv<'a> {
        PagedKv { pool, blocks }
    }

    fn row(&self, li: usize, t: usize, v_plane: bool) -> (usize, usize) {
        let bt = self.pool.block_tokens;
        let d = self.pool.d_model;
        let block = self.blocks[t / bt];
        let (k_base, v_base) = self.pool.layer_base(block, li);
        let base = if v_plane { v_base } else { k_base };
        let start = base + (t % bt) * d;
        (start, start + d)
    }
}

impl KvStore for PagedKv<'_> {
    fn write_row(&mut self, li: usize, pos: usize, k: &[f32], v: &[f32]) {
        let (ks, ke) = self.row(li, pos, false);
        self.pool.slab[ks..ke].copy_from_slice(k);
        let (vs, ve) = self.row(li, pos, true);
        self.pool.slab[vs..ve].copy_from_slice(v);
    }

    fn k_row(&self, li: usize, t: usize) -> &[f32] {
        let (s, e) = self.row(li, t, false);
        &self.pool.slab[s..e]
    }

    fn v_row(&self, li: usize, t: usize) -> &[f32] {
        let (s, e) = self.row(li, t, true);
        &self.pool.slab[s..e]
    }
}

/// A bundle + adapter state compiled for incremental decoding: token
/// step cost is O(T) in cache length instead of the O(T²) full
/// re-forward `logits_last` pays per generated token.
pub struct DecodeModel {
    dims: ModelDims,
    tok_emb: Tensor,
    pos_emb: Tensor,
    final_norm: Vec<f32>,
    lm_head: Tensor,
    layers: Vec<DecLayer>,
}

impl RefBundle {
    /// Resolve trainables + fixed inputs into a [`DecodeModel`] —
    /// adapter merging happens here, once; quantized bases are carried
    /// packed into the decode loop.
    pub fn decode_model(&self, trainables: &[&Value], fixed: &[&Value]) -> Result<DecodeModel> {
        let params = self.assemble_params(trainables, fixed)?;
        let norm = |name: &str| -> Result<Vec<f32>> { Ok(params.get(name)?.data.clone()) };
        let linear =
            |name: &str| -> Result<Box<dyn DecodeApply>> { self.resolve_linear(&params, name) };
        let mut layers = Vec::with_capacity(self.dims.n_layers);
        for i in 0..self.dims.n_layers {
            let pre = format!("layers.{i}");
            layers.push(DecLayer {
                attn_norm: norm(&format!("{pre}.attn.norm"))?,
                wq: linear(&format!("{pre}.attn.wq"))?,
                wk: linear(&format!("{pre}.attn.wk"))?,
                wv: linear(&format!("{pre}.attn.wv"))?,
                wo: linear(&format!("{pre}.attn.wo"))?,
                mlp_norm: norm(&format!("{pre}.mlp.norm"))?,
                up: linear(&format!("{pre}.mlp.up"))?,
                down: linear(&format!("{pre}.mlp.down"))?,
            });
        }
        Ok(DecodeModel {
            dims: self.dims,
            tok_emb: params.get("embed.tok")?.clone(),
            pos_emb: params.get("embed.pos")?.clone(),
            final_norm: norm("final_norm")?,
            lm_head: params.get("lm_head")?.clone(),
            layers,
        })
    }

    /// Resolve one adapted linear into its method's decode applier
    /// (adapter state merged once here, never per token). Linears the
    /// scenario targeting deselected resolve through the identity
    /// (`none`) adapter — the frozen base, as in training.
    fn resolve_linear(&self, params: &Params, name: &str) -> Result<Box<dyn DecodeApply>> {
        let w = params.weight(name)?;
        if self.skipped.contains(name) {
            return crate::adapters::get("none")?.resolve_decode(params, &self.dims, name, w);
        }
        self.adapter.resolve_decode(params, &self.dims, name, w)
    }
}

impl DecodeModel {
    pub fn seq_len(&self) -> usize {
        self.dims.seq_len
    }

    pub fn vocab(&self) -> usize {
        self.dims.vocab
    }

    pub fn dims(&self) -> &ModelDims {
        &self.dims
    }

    /// Empty cache sized for one sequence.
    pub fn new_cache(&self) -> KvCache {
        let plane = self.dims.seq_len * self.dims.d_model;
        KvCache {
            k: (0..self.dims.n_layers).map(|_| vec![0f32; plane]).collect(),
            v: (0..self.dims.n_layers).map(|_| vec![0f32; plane]).collect(),
            d_model: self.dims.d_model,
            len: 0,
        }
    }

    /// Incremental forward: consume `token` at position `cache.len`
    /// and return the next-token logits (V,). Only the new token's
    /// activations are computed (and, for OFTv2/QOFT, rotated) —
    /// attention reads keys/values from the per-sequence cache, so a
    /// T-token greedy decode is O(T) forwards of one row instead of
    /// the O(T²) whole-sequence re-forwards `logits_last` pays.
    pub fn forward_incremental(&self, cache: &mut KvCache, token: i32) -> Result<Vec<f32>> {
        let pos = cache.len;
        let logits = self.forward_step(cache, pos, token)?;
        cache.len = pos + 1;
        Ok(logits)
    }

    /// One decode step against any [`KvStore`] layout. The arithmetic
    /// and its evaluation order are shared verbatim between contiguous
    /// and paged storage, so the two produce bitwise-identical logits;
    /// only row addressing differs. `kv` must have backing rows for
    /// positions `0..=pos`, with `0..pos` previously written.
    pub fn forward_step<K: KvStore>(
        &self,
        kv: &mut K,
        pos: usize,
        token: i32,
    ) -> Result<Vec<f32>> {
        let d = self.dims.d_model;
        let t = self.dims.seq_len;
        let h = self.dims.n_heads;
        let hd = d / h;
        ensure!(pos < t, "KV cache full: position {pos} of seq_len {t}");
        ensure!(
            token >= 0 && (token as usize) < self.dims.vocab,
            "token id {token} out of vocab {}",
            self.dims.vocab
        );

        let mut x = Tensor::zeros(&[1, d]);
        {
            let te = &self.tok_emb.data[token as usize * d..(token as usize + 1) * d];
            let pe = &self.pos_emb.data[pos * d..(pos + 1) * d];
            for j in 0..d {
                x.data[j] = te[j] + pe[j];
            }
        }

        for (li, layer) in self.layers.iter().enumerate() {
            let (xn1, _) = rmsnorm_fwd(&x, &layer.attn_norm);
            let q = layer.wq.apply(&xn1)?;
            let k = layer.wk.apply(&xn1)?;
            let v = layer.wv.apply(&xn1)?;
            kv.write_row(li, pos, &k.data, &v.data);

            // Single-query causal attention over the cache; loop order
            // mirrors attention_fwd so results match bitwise.
            let scale = 1.0 / (hd as f32).sqrt();
            let mut o = Tensor::zeros(&[1, d]);
            for hh in 0..h {
                let qoff = hh * hd;
                let mut row = vec![0f32; pos + 1];
                let mut maxv = f32::NEG_INFINITY;
                for (t2, rv) in row.iter_mut().enumerate() {
                    let krow = kv.k_row(li, t2);
                    let mut acc = 0f32;
                    for c in 0..hd {
                        acc += q.data[qoff + c] * krow[hh * hd + c];
                    }
                    *rv = acc * scale;
                    maxv = maxv.max(*rv);
                }
                let mut sum = 0f32;
                for rv in &mut row {
                    *rv = (*rv - maxv).exp();
                    sum += *rv;
                }
                for (t2, rv) in row.iter().enumerate() {
                    let a = rv / sum;
                    let vrow = kv.v_row(li, t2);
                    for c in 0..hd {
                        o.data[qoff + c] += a * vrow[hh * hd + c];
                    }
                }
            }

            let ywo = layer.wo.apply(&o)?;
            let x_mid = x.add(&ywo)?;
            let (xn2, _) = rmsnorm_fwd(&x_mid, &layer.mlp_norm);
            let up_pre = layer.up.apply(&xn2)?;
            let act = gelu_fwd(&up_pre);
            let ydown = layer.down.apply(&act)?;
            x = x_mid.add(&ydown)?;
        }

        let (xf, _) = rmsnorm_fwd(&x, &self.final_norm);
        let logits = xf.matmul(&self.lm_head)?;
        Ok(logits.data)
    }
}

// ---------------------------------------------------------------------------
// Shared helpers
// ---------------------------------------------------------------------------

fn value_tensor(v: &Value, shape: &[usize]) -> Result<Tensor> {
    let data = v.f32s()?;
    ensure!(
        data.len() == shape.iter().product::<usize>(),
        "input has {} elements, shape {shape:?} wants {}",
        data.len(),
        shape.iter().product::<usize>()
    );
    Ok(Tensor::from_vec(shape, data.to_vec()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::manifest::Manifest;
    use crate::util::rng::Rng;

    fn bundle(tag: &str) -> RefBundle {
        RefBundle::from_manifest(&Manifest::builtin(tag).unwrap()).unwrap()
    }

    fn random_values(specs: &[ParamSpec], std: f32, seed: u64) -> Vec<Value> {
        let mut rng = Rng::new(seed);
        specs
            .iter()
            .map(|s| lit_f32(&s.shape, &rng.normal_vec(s.numel(), std)).unwrap())
            .collect()
    }

    fn batch(bu: &RefBundle, seed: u64) -> (Value, Value) {
        let (b, t) = (bu.dims.batch, bu.dims.seq_len);
        let mut rng = Rng::new(seed);
        let toks: Vec<i32> = (0..b * (t + 1))
            .map(|_| rng.below(bu.dims.vocab) as i32)
            .collect();
        let mask = vec![1.0f32; b * t];
        (
            super::super::lit_i32(&[b, t + 1], &toks).unwrap(),
            lit_f32(&[b, t], &mask).unwrap(),
        )
    }

    /// Run train_step at lr=0 (returns pre-update loss; new_m encodes
    /// the raw gradient as new_m = (1-b1) g when m starts at zero).
    fn step_outputs_opts(
        bu: &RefBundle,
        tr: &[Value],
        toks: &Value,
        mask: &Value,
        opts: TrainOpts,
    ) -> Vec<Value> {
        let n = tr.len();
        let zeros: Vec<Value> = bu
            .trainable
            .iter()
            .map(|s| lit_f32(&s.shape, &vec![0.0; s.numel()]).unwrap())
            .collect();
        // realistic frozen base (norms at 1, weights ~N(0, 0.02)) so
        // gradient magnitudes are representative
        let fixed: Vec<Value> = bu
            .frozen
            .iter()
            .map(|s| {
                let t = crate::coordinator::state::init_param(s, 99, None).unwrap();
                lit_f32(&s.shape, &t.data).unwrap()
            })
            .collect();
        let mut inputs: Vec<&Value> = Vec::new();
        inputs.extend(tr.iter());
        inputs.extend(zeros.iter());
        inputs.extend(zeros.iter());
        inputs.extend(fixed.iter());
        let lr = super::super::lit_scalar_f32(0.0);
        let t1 = super::super::lit_scalar_f32(1.0);
        inputs.push(toks);
        inputs.push(mask);
        inputs.push(&lr);
        inputs.push(&t1);
        let out = bu.train_step_opts(&inputs, opts).unwrap();
        assert_eq!(out.len(), 3 * n + 1);
        out
    }

    fn step_outputs(bu: &RefBundle, tr: &[Value], toks: &Value, mask: &Value) -> Vec<Value> {
        step_outputs_opts(bu, tr, toks, mask, TrainOpts::default())
    }

    #[test]
    fn train_step_gradients_match_finite_differences() {
        // Non-trivial adapter state; gradient recovered from the first
        // Adam moment at m0 = 0: new_m = (1 - b1) g. Runs for the CNP
        // method (oft_v2) AND both registry-added methods (boft, hoft)
        // so every new backward is FD-locked, not just type-checked.
        for tag in ["tiny_oft_v2", "tiny_boft", "tiny_hoft"] {
            let bu = bundle(tag);
            let n = bu.n_trainable();
            let tr = random_values(&bu.trainable, 0.02, 5);
            let (toks, mask) = batch(&bu, 7);
            let out = step_outputs(&bu, &tr, &toks, &mask);
            let loss0 = scalar_f32(&out[3 * n]).unwrap();
            assert!(loss0.is_finite() && loss0 > 0.0, "{tag}: loss {loss0}");

            // pick the largest-|g| coordinate of the first adapter
            let g: Vec<f32> = out[n].to_vec::<f32>().unwrap();
            let grad: Vec<f32> = g.iter().map(|x| x / (1.0 - 0.9)).collect();
            let (best, gbest) = grad
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
                .map(|(i, g)| (i, *g))
                .unwrap();
            assert!(gbest.abs() > 0.0, "{tag}: zero gradient everywhere");

            let eps = 2e-2f32;
            let eval_at = |delta: f32| -> f32 {
                let mut tr2 = tr.clone();
                let mut data = tr2[0].to_vec::<f32>().unwrap();
                data[best] += delta;
                tr2[0] = lit_f32(&bu.trainable[0].shape, &data).unwrap();
                let out = step_outputs(&bu, &tr2, &toks, &mask);
                scalar_f32(&out[3 * n]).unwrap()
            };
            let fd = (eval_at(eps) - eval_at(-eps)) / (2.0 * eps);
            let rel = (fd - gbest).abs() / gbest.abs().max(1e-4);
            assert!(rel < 0.25, "{tag}: FD {fd} vs analytic {gbest} (rel {rel})");
        }
    }

    #[test]
    fn boft_second_factor_gradients_match_finite_differences() {
        // The generic FD test perturbs the first sorted trainable — for
        // tiny_boft a depth-1 attention linear — so the multi-factor
        // dpack slices (rows nb.. of a depth-2 parameter) would go
        // unchecked. Lock them explicitly on a d_ff=256 MLP linear
        // (b=16 -> nb=16, m=2): FD a coordinate chosen from the SECOND
        // factor's packed rows.
        let bu = bundle("tiny_boft");
        let n = bu.n_trainable();
        let tr = random_values(&bu.trainable, 0.02, 29);
        let (toks, mask) = batch(&bu, 31);
        let out = step_outputs(&bu, &tr, &toks, &mask);

        let (pi, spec) = bu
            .trainable
            .iter()
            .enumerate()
            .find(|(_, s)| s.name == "layers.0.mlp.down.boft_q")
            .expect("tiny boft bundle lost its mlp.down parameter");
        let p = crate::peft::packed_dim(bu.dims.block_b);
        let nb = 256 / bu.dims.block_b; // mlp.down input width / b
        assert_eq!(spec.shape, vec![2 * nb, p], "expected a depth-2 parameter");

        let g: Vec<f32> = out[n + pi].to_vec::<f32>().unwrap();
        let grad: Vec<f32> = g.iter().map(|x| x / (1.0 - 0.9)).collect();
        let (best, gbest) = grad
            .iter()
            .enumerate()
            .skip(nb * p) // restrict to factor 1's rows
            .max_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
            .map(|(i, g)| (i, *g))
            .unwrap();
        assert!(gbest.abs() > 0.0, "second-factor gradient identically zero");

        let eps = 2e-2f32;
        let eval_at = |delta: f32| -> f32 {
            let mut tr2 = tr.clone();
            let mut data = tr2[pi].to_vec::<f32>().unwrap();
            data[best] += delta;
            tr2[pi] = lit_f32(&spec.shape, &data).unwrap();
            let out = step_outputs(&bu, &tr2, &toks, &mask);
            scalar_f32(&out[3 * n]).unwrap()
        };
        let fd = (eval_at(eps) - eval_at(-eps)) / (2.0 * eps);
        let rel = (fd - gbest).abs() / gbest.abs().max(1e-4);
        assert!(rel < 0.25, "FD {fd} vs analytic {gbest} (rel {rel})");
    }

    #[test]
    fn lora_b_gradient_nonzero_and_a_zero_at_init() {
        // At B = 0: dL/dA = 0 exactly, dL/dB != 0 — a sharp analytic
        // property of the LoRA backward.
        let bu = bundle("tiny_lora");
        let n = bu.n_trainable();
        let mut rng = Rng::new(3);
        let tr: Vec<Value> = bu
            .trainable
            .iter()
            .map(|s| {
                if s.name.ends_with(".lora_a") {
                    lit_f32(&s.shape, &rng.normal_vec(s.numel(), 0.01)).unwrap()
                } else {
                    lit_f32(&s.shape, &vec![0.0; s.numel()]).unwrap()
                }
            })
            .collect();
        let (toks, mask) = batch(&bu, 11);
        let out = step_outputs(&bu, &tr, &toks, &mask);
        let mut saw_b = false;
        for (i, spec) in bu.trainable.iter().enumerate() {
            let g: Vec<f32> = out[n + i].to_vec::<f32>().unwrap();
            let gmax = g.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
            if spec.name.ends_with(".lora_a") {
                assert!(gmax < 1e-12, "{}: dA should be 0 at B=0, got {gmax}", spec.name);
            } else {
                saw_b = saw_b || gmax > 1e-9;
            }
        }
        assert!(saw_b, "all lora_b gradients vanished");
    }

    #[test]
    fn checkpointing_and_workers_do_not_change_step_outputs() {
        // The acceptance property at the graph level: every TrainOpts
        // combination must produce bitwise-identical step outputs
        // (loss, updated params, Adam moments).
        for tag in [
            "tiny_oft_v2",
            "tiny_lora",
            "tiny_oft_merged",
            "tiny_boft",
            "tiny_hoft",
        ] {
            let bu = bundle(tag);
            let tr = random_values(&bu.trainable, 0.02, 13);
            let (toks, mask) = batch(&bu, 17);
            let base = step_outputs(&bu, &tr, &toks, &mask);
            let o = |checkpoint, workers| TrainOpts {
                checkpoint,
                workers,
                ..Default::default()
            };
            for opts in [
                o(CheckpointPolicy::EveryK(1), 1),
                o(CheckpointPolicy::EveryK(2), 1),
                o(CheckpointPolicy::None, 4),
                o(CheckpointPolicy::EveryK(2), 3),
            ] {
                let out = step_outputs_opts(&bu, &tr, &toks, &mask, opts);
                assert_eq!(base.len(), out.len());
                for (i, (a, b)) in base.iter().zip(&out).enumerate() {
                    assert_eq!(
                        a, b,
                        "{tag}: output {i} differs under {:?}/{} workers",
                        opts.checkpoint, opts.workers
                    );
                }
            }
        }
    }

    #[test]
    fn tree_reduce_shape_is_fixed() {
        // ((1+2)+(3+4))+5 — pairwise, order by index.
        let got = tree_reduce(vec![1, 2, 3, 4, 5], |a, b| a + b).unwrap();
        assert_eq!(got, 15);
        assert_eq!(tree_reduce(Vec::<i32>::new(), |a, b| a + b), None);
        assert_eq!(tree_reduce(vec![7], |a, b| a + b), Some(7));
    }

    #[test]
    fn incremental_forward_matches_logits_last_exactly() {
        // The KV-cached row-at-a-time forward must reproduce the padded
        // whole-sequence forward's last-position logits exactly (same
        // kernels, same per-row accumulation order).
        for tag in [
            "tiny_oft_v2",
            "tiny_lora",
            "tiny_oft_merged",
            "tiny_boft",
            "tiny_hoft",
        ] {
            let bu = bundle(tag);
            let tr = random_values(&bu.trainable, 0.05, 21);
            let fixed: Vec<Value> = bu
                .frozen
                .iter()
                .map(|s| {
                    let t = crate::coordinator::state::init_param(s, 3, None).unwrap();
                    lit_f32(&s.shape, &t.data).unwrap()
                })
                .collect();
            let tr_refs: Vec<&Value> = tr.iter().collect();
            let fixed_refs: Vec<&Value> = fixed.iter().collect();

            let model = bu.decode_model(&tr_refs, &fixed_refs).unwrap();
            let mut cache = model.new_cache();
            let toks = [1i32, 7, 3, 9, 2];
            let mut inc = Vec::new();
            for &tk in &toks {
                inc = model.forward_incremental(&mut cache, tk).unwrap();
            }
            assert_eq!(cache.position(), toks.len());

            let t = bu.dims.seq_len;
            let mut padded: Vec<i32> = toks.to_vec();
            padded.resize(t, 0);
            let tokens = super::super::lit_i32(&[1, t], &padded).unwrap();
            let cur = super::super::lit_scalar_i32(toks.len() as i32);
            let mut inputs: Vec<&Value> = tr_refs.clone();
            inputs.extend(fixed_refs.iter().copied());
            inputs.push(&tokens);
            inputs.push(&cur);
            let out = bu.logits_last(&inputs).unwrap();
            assert_eq!(
                out[0].f32s().unwrap(),
                inc.as_slice(),
                "{tag}: incremental logits diverged from logits_last"
            );
        }
    }

    #[test]
    fn paged_kv_matches_contiguous_cache_bitwise() {
        // The paged block layout must be invisible to the arithmetic:
        // stepping through blocks of a shared pool yields the exact
        // logits of the per-session contiguous cache, even with a
        // deliberately awkward block size and dirty recycled blocks.
        for tag in ["tiny_oft_v2", "tiny_lora", "tiny_boft"] {
            let bu = bundle(tag);
            let tr = random_values(&bu.trainable, 0.05, 21);
            let fixed: Vec<Value> = bu
                .frozen
                .iter()
                .map(|s| {
                    let t = crate::coordinator::state::init_param(s, 3, None).unwrap();
                    lit_f32(&s.shape, &t.data).unwrap()
                })
                .collect();
            let tr_refs: Vec<&Value> = tr.iter().collect();
            let fixed_refs: Vec<&Value> = fixed.iter().collect();
            let model = bu.decode_model(&tr_refs, &fixed_refs).unwrap();

            let mut pool =
                KvBlockPool::new(bu.dims.n_layers, bu.dims.d_model, 3, 8).unwrap();
            // Dirty a block and recycle it: sessions must never read
            // positions they did not write.
            let dirty = pool.alloc().unwrap();
            for x in pool.slab.iter_mut() {
                *x = f32::NAN;
            }
            pool.release(dirty);

            let mut cache = model.new_cache();
            let mut blocks: Vec<u32> = Vec::new();
            let toks = [1i32, 7, 3, 9, 2, 5, 4];
            for (pos, &tk) in toks.iter().enumerate() {
                let contiguous = model.forward_incremental(&mut cache, tk).unwrap();
                if pos >= blocks.len() * pool.block_tokens() {
                    blocks.push(pool.alloc().unwrap());
                }
                let mut view = PagedKv::new(&mut pool, &blocks);
                let paged = model.forward_step(&mut view, pos, tk).unwrap();
                assert_eq!(contiguous, paged, "{tag}: paged logits diverged at {pos}");
            }
            assert_eq!(blocks.len(), 3, "7 tokens over block_tokens=3");
            for id in blocks {
                pool.release(id);
            }
            assert_eq!(pool.stats().in_use, 0);
        }
    }

    #[test]
    fn kv_pool_bounds_blocks_and_recycles_the_free_list() {
        let mut pool = KvBlockPool::new(2, 4, 4, 2).unwrap();
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        assert_eq!(pool.available(), 0);
        let err = pool.alloc().unwrap_err().to_string();
        assert!(err.contains("exhausted"), "want exhaustion error, got: {err}");
        pool.release(a);
        // The freed block is recycled — the slab never grows past the
        // cap however many sessions come and go.
        let c = pool.alloc().unwrap();
        assert_eq!(c, a);
        pool.release(b);
        pool.release(c);
        let s = pool.stats();
        assert_eq!(s.slab_blocks, 2);
        assert_eq!(s.in_use, 0);
        assert_eq!(s.peak_in_use, 2);
        assert_eq!(s.total_allocs, 3);
        assert_eq!(pool.blocks_for(0), 0);
        assert_eq!(pool.blocks_for(4), 1);
        assert_eq!(pool.blocks_for(5), 2);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "not allocated")]
    fn kv_pool_double_release_asserts_in_debug() {
        let mut pool = KvBlockPool::new(1, 2, 4, 2).unwrap();
        let a = pool.alloc().unwrap();
        pool.release(a);
        pool.release(a);
    }

    #[test]
    #[cfg(not(debug_assertions))]
    fn kv_pool_double_release_is_ignored_in_release() {
        let mut pool = KvBlockPool::new(1, 2, 4, 2).unwrap();
        let a = pool.alloc().unwrap();
        pool.release(a);
        pool.release(a); // must not enter the free list twice
        let b = pool.alloc().unwrap();
        let c = pool.alloc().unwrap();
        assert_ne!(b, c, "double release aliased two sessions onto one block");
        assert_eq!(pool.stats().in_use, 2);
    }

    #[test]
    fn kv_pool_capacity_grows_never_shrinks() {
        let mut pool = KvBlockPool::new(1, 2, 4, 1).unwrap();
        let a = pool.alloc().unwrap();
        assert!(pool.alloc().is_err(), "at capacity");
        pool.grow_capacity(2);
        let b = pool.alloc().unwrap();
        assert_ne!(a, b);
        pool.grow_capacity(1); // never shrinks
        assert_eq!(pool.stats().capacity_blocks, 2);
        pool.release(a);
        pool.release(b);
    }

    #[test]
    fn method_resolution_comes_from_the_registry() {
        // Bundles resolve their method through the adapter registry —
        // the closed enum is gone, so a registered method IS a valid
        // bundle method, with no second list to keep in sync.
        let bu = bundle("tiny_hoft");
        assert_eq!(bu.adapter.name(), "hoft");
        assert!(RefBundle::from_manifest(&Manifest::builtin("tiny_boft").unwrap()).is_ok());
        assert_eq!(QuantKind::parse("nf4").unwrap(), QuantKind::Nf4);
    }

    #[test]
    fn parse_errors_list_valid_options() {
        // Mirrors the `--backend` fix: an unknown name teaches the
        // valid spellings instead of just rejecting.
        let err = match crate::adapters::get("bogus") {
            Err(e) => format!("{e:#}"),
            Ok(a) => panic!("bogus resolved to '{}'", a.name()),
        };
        for name in crate::adapters::names() {
            assert!(err.contains(name), "method error should list '{name}': {err}");
        }
        let err = match QuantKind::parse("int3") {
            Err(e) => format!("{e:#}"),
            Ok(q) => panic!("int3 parsed as {q:?}"),
        };
        for name in ["none", "nf4", "awq"] {
            assert!(err.contains(name), "quant error should list '{name}': {err}");
        }
    }
}
