//! Native execution of a bundle's graphs: the pure-Rust twin of the L2
//! JAX model (python/compile/model.py), used by the reference engine.
//!
//! Implements the decoder-only transformer with every PEFT method of
//! the paper (full / none / LoRA / weight-centric OFT / input-centric
//! OFTv2 / QLoRA / QOFT), a hand-derived backward pass, and the Adam
//! update — so `train_step`, `eval_loss` and `logits_last` run without
//! artifacts, Python, or an accelerator.
//!
//! Every gradient formula here is locked against `jax.grad` of the L2
//! model by `python/tests/test_ref_backward.py`; the Rust code is a 1:1
//! transcription of that file's numpy mirror. The OFTv2 forward is
//! matrix-free: inputs are rotated block-by-block (quadratic work)
//! instead of merging `blockdiag(R) @ W` (cubic work) — see §3 of the
//! paper. The weight-centric baseline deliberately *does* materialize
//! the merge so timing comparisons remain honest.

use std::collections::BTreeMap;

use anyhow::{bail, ensure, Context, Result};

use super::{lit_f32, scalar_f32, Value};
use crate::coordinator::manifest::{Manifest, ModelDims, ParamSpec, QuantSpec};
use crate::peft;
use crate::quant::{AwqTensor, Nf4Tensor};
use crate::tensor::Tensor;

/// PEFT method of a bundle (mirrors configs.METHODS).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    Full,
    None,
    Lora,
    OftMerged,
    OftV2,
    QLora,
    QOft,
}

impl Method {
    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s {
            "full" => Method::Full,
            "none" => Method::None,
            "lora" => Method::Lora,
            "oft_merged" => Method::OftMerged,
            "oft_v2" => Method::OftV2,
            "qlora" => Method::QLora,
            "qoft" => Method::QOft,
            other => bail!("unknown method '{other}'"),
        })
    }

    /// LoRA-family method (additive low-rank adapter)?
    pub fn is_lora(self) -> bool {
        matches!(self, Method::Lora | Method::QLora)
    }

    /// Input-centric OFT-family method (matrix-free rotation)?
    pub fn is_oft_input_centric(self) -> bool {
        matches!(self, Method::OftV2 | Method::QOft)
    }
}

/// Weight storage backend for quantized methods.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantKind {
    None,
    Nf4,
    Awq,
}

impl QuantKind {
    pub fn parse(s: &str) -> Result<QuantKind> {
        Ok(match s {
            "none" => QuantKind::None,
            "nf4" => QuantKind::Nf4,
            "awq" => QuantKind::Awq,
            other => bail!("unknown quant backend '{other}'"),
        })
    }
}

/// A bundle's native executor: dims + method + the manifest's input
/// contract, ready to run any of the three graphs.
pub struct RefBundle {
    pub dims: ModelDims,
    pub method: Method,
    pub quant: QuantKind,
    trainable: Vec<ParamSpec>,
    frozen: Vec<ParamSpec>,
    quantized: Vec<QuantSpec>,
    adam: (f64, f64, f64),
}

impl RefBundle {
    pub fn from_manifest(man: &Manifest) -> Result<RefBundle> {
        let method = Method::parse(&man.method)?;
        let quant = QuantKind::parse(&man.quant)?;
        ensure!(
            man.model.d_model % man.model.n_heads == 0,
            "d_model {} not divisible by n_heads {}",
            man.model.d_model,
            man.model.n_heads
        );
        Ok(RefBundle {
            dims: man.model,
            method,
            quant,
            trainable: man.trainable.clone(),
            frozen: man.frozen.clone(),
            quantized: man.quantized.clone(),
            adam: man.adam,
        })
    }

    pub fn n_trainable(&self) -> usize {
        self.trainable.len()
    }

    fn n_fixed(&self) -> usize {
        self.frozen.len() + self.quantized.len()
    }

    /// (din, dout) of an adapted linear (mirrors manifest.linear_shape).
    fn linear_shape(&self, base: &str) -> Result<(usize, usize)> {
        let (d, f) = (self.dims.d_model, self.dims.d_ff);
        if base.ends_with(".mlp.up") {
            Ok((d, f))
        } else if base.ends_with(".mlp.down") {
            Ok((f, d))
        } else if base.contains(".attn.w") {
            Ok((d, d))
        } else {
            bail!("'{base}' is not an adapted linear weight")
        }
    }

    // -----------------------------------------------------------------
    // Parameter assembly
    // -----------------------------------------------------------------

    /// Name -> tensor map from graph inputs: trainables + frozen f32 +
    /// dequantized base weights (NF4/AWQ packs are decoded here, the
    /// role the Pallas dequant kernels play on the accelerator).
    fn assemble_params(&self, trainables: &[&Value], fixed: &[&Value]) -> Result<Params> {
        ensure!(
            trainables.len() == self.trainable.len(),
            "expected {} trainable inputs, got {}",
            self.trainable.len(),
            trainables.len()
        );
        ensure!(
            fixed.len() == self.n_fixed(),
            "expected {} fixed inputs, got {}",
            self.n_fixed(),
            fixed.len()
        );
        let mut map = BTreeMap::new();
        for (spec, v) in self.trainable.iter().zip(trainables) {
            map.insert(spec.name.clone(), value_tensor(v, &spec.shape)?);
        }
        for (spec, v) in self.frozen.iter().zip(&fixed[..self.frozen.len()]) {
            map.insert(spec.name.clone(), value_tensor(v, &spec.shape)?);
        }
        if !self.quantized.is_empty() {
            let packs: Vec<(&QuantSpec, &Value)> = self
                .quantized
                .iter()
                .zip(&fixed[self.frozen.len()..])
                .map(|(s, v)| (s, *v))
                .collect();
            let mut seen: Vec<String> = Vec::new();
            for (spec, _) in &packs {
                if !seen.contains(&spec.base) {
                    seen.push(spec.base.clone());
                }
            }
            for base in seen {
                let w = self.dequantize_base(&base, &packs)?;
                map.insert(base, w);
            }
        }
        Ok(Params { map })
    }

    fn dequantize_base(&self, base: &str, packs: &[(&QuantSpec, &Value)]) -> Result<Tensor> {
        let (din, dout) = self.linear_shape(base)?;
        let field = |suffix: &str| -> Result<&Value> {
            packs
                .iter()
                .find(|(s, _)| s.base == base && s.name.ends_with(suffix))
                .map(|(_, v)| *v)
                .with_context(|| format!("missing pack '{base}.{suffix}'"))
        };
        match self.quant {
            QuantKind::Nf4 => {
                let q = Nf4Tensor {
                    codes: field("nf4_codes")?.u8s()?.to_vec(),
                    absmax_q: field("nf4_absmax_q")?.i8s()?.to_vec(),
                    absmax_s: field("nf4_absmax_s")?.f32s()?.to_vec(),
                    offset: field("nf4_offset")?.f32s()?[0],
                    n: din * dout,
                    shape: vec![din, dout],
                };
                Ok(q.dequantize())
            }
            QuantKind::Awq => {
                let q = AwqTensor {
                    codes: field("awq_codes")?.u8s()?.to_vec(),
                    scales: field("awq_scales")?.f32s()?.to_vec(),
                    eq: field("awq_eq")?.f32s()?.to_vec(),
                    din,
                    dout,
                };
                Ok(q.dequantize())
            }
            QuantKind::None => bail!("bundle has quantized packs but quant backend 'none'"),
        }
    }

    // -----------------------------------------------------------------
    // Graph entry points (manifest I/O contracts)
    // -----------------------------------------------------------------

    /// `train_step(tr, m, v, fixed, tokens, mask, lr, t)` ->
    /// `new_tr + new_m + new_v + [loss]`.
    pub fn train_step(&self, inputs: &[&Value]) -> Result<Vec<Value>> {
        let n = self.trainable.len();
        let want = 3 * n + self.n_fixed() + 4;
        ensure!(
            inputs.len() == want,
            "train_step expected {want} inputs, got {}",
            inputs.len()
        );
        let tr = &inputs[..n];
        let mom_m = &inputs[n..2 * n];
        let mom_v = &inputs[2 * n..3 * n];
        let fixed = &inputs[3 * n..3 * n + self.n_fixed()];
        let data = &inputs[3 * n + self.n_fixed()..];
        let tokens = data[0].i32s()?;
        let mask = data[1].f32s()?;
        let lr = scalar_f32(data[2])?;
        let t_step = scalar_f32(data[3])?;

        let params = self.assemble_params(tr, fixed)?;
        let (loss, mut grads) = self.loss_and_grads(&params, tokens, mask)?;

        let (b1, b2, eps) = (self.adam.0 as f32, self.adam.1 as f32, self.adam.2 as f32);
        let bc1 = 1.0 - b1.powf(t_step);
        let bc2 = 1.0 - b2.powf(t_step);
        let mut new_p = Vec::with_capacity(n);
        let mut new_m = Vec::with_capacity(n);
        let mut new_v = Vec::with_capacity(n);
        for (i, spec) in self.trainable.iter().enumerate() {
            let g = grads
                .remove(&spec.name)
                .unwrap_or_else(|| Tensor::zeros(&spec.shape));
            ensure!(
                g.numel() == spec.numel(),
                "gradient for '{}' has {} elements, want {}",
                spec.name,
                g.numel(),
                spec.numel()
            );
            let p = tr[i].f32s()?;
            let m0 = mom_m[i].f32s()?;
            let v0 = mom_v[i].f32s()?;
            let numel = spec.numel();
            let mut pn = vec![0f32; numel];
            let mut mn = vec![0f32; numel];
            let mut vn = vec![0f32; numel];
            for j in 0..numel {
                let gj = g.data[j];
                let mm = b1 * m0[j] + (1.0 - b1) * gj;
                let vv = b2 * v0[j] + (1.0 - b2) * gj * gj;
                let mhat = mm / bc1;
                let vhat = vv / bc2;
                mn[j] = mm;
                vn[j] = vv;
                pn[j] = p[j] - lr * mhat / (vhat.sqrt() + eps);
            }
            new_p.push(lit_f32(&spec.shape, &pn)?);
            new_m.push(lit_f32(&spec.shape, &mn)?);
            new_v.push(lit_f32(&spec.shape, &vn)?);
        }
        let mut out = new_p;
        out.extend(new_m);
        out.extend(new_v);
        out.push(super::lit_scalar_f32(loss));
        Ok(out)
    }

    /// `eval_loss(tr, fixed, tokens, mask)` -> `(sum_nll, token_count)`.
    pub fn eval_loss(&self, inputs: &[&Value]) -> Result<Vec<Value>> {
        let n = self.trainable.len();
        let want = n + self.n_fixed() + 2;
        ensure!(
            inputs.len() == want,
            "eval_loss expected {want} inputs, got {}",
            inputs.len()
        );
        let tr = &inputs[..n];
        let fixed = &inputs[n..n + self.n_fixed()];
        let tokens = inputs[n + self.n_fixed()].i32s()?;
        let mask = inputs[n + self.n_fixed() + 1].f32s()?;
        let params = self.assemble_params(tr, fixed)?;

        let (bsz, t) = (self.dims.batch, self.dims.seq_len);
        ensure!(tokens.len() == bsz * (t + 1), "tokens shape mismatch");
        ensure!(mask.len() == bsz * t, "mask shape mismatch");
        self.validate_token_ids(tokens)?;
        let (inputs_ids, targets) = split_tokens(tokens, bsz, t);
        let fwd = self.forward(&params, &inputs_ids, bsz)?;
        let (sum_nll, count, _) = nll_stats(&fwd.logits, &targets, mask);
        Ok(vec![
            super::lit_scalar_f32(sum_nll),
            super::lit_scalar_f32(count),
        ])
    }

    /// `logits_last(tr, fixed, tokens (1, T) i32, cur_len i32)` ->
    /// `(logits (V,),)`.
    pub fn logits_last(&self, inputs: &[&Value]) -> Result<Vec<Value>> {
        let n = self.trainable.len();
        let want = n + self.n_fixed() + 2;
        ensure!(
            inputs.len() == want,
            "logits_last expected {want} inputs, got {}",
            inputs.len()
        );
        let tr = &inputs[..n];
        let fixed = &inputs[n..n + self.n_fixed()];
        let tokens = inputs[n + self.n_fixed()].i32s()?;
        let cur = inputs[n + self.n_fixed() + 1].i32s()?[0];
        let params = self.assemble_params(tr, fixed)?;

        let t = self.dims.seq_len;
        let v = self.dims.vocab;
        ensure!(tokens.len() == t, "logits_last tokens must be (1, {t})");
        let fwd = self.forward(&params, tokens, 1)?;
        let idx = (cur - 1).clamp(0, t as i32 - 1) as usize;
        let row = fwd.logits.data[idx * v..(idx + 1) * v].to_vec();
        Ok(vec![lit_f32(&[v], &row)?])
    }

    /// Reject out-of-vocab (or negative) ids up front: targets index
    /// the log-prob rows directly, so a bad id must surface as an error
    /// rather than an out-of-bounds panic.
    fn validate_token_ids(&self, tokens: &[i32]) -> Result<()> {
        let vocab = self.dims.vocab;
        for &id in tokens {
            ensure!(
                id >= 0 && (id as usize) < vocab,
                "token id {id} out of vocab {vocab}"
            );
        }
        Ok(())
    }

    // -----------------------------------------------------------------
    // Forward
    // -----------------------------------------------------------------

    fn forward(&self, params: &Params, input_ids: &[i32], bsz: usize) -> Result<Fwd> {
        let t = self.dims.seq_len;
        let d = self.dims.d_model;
        let h = self.dims.n_heads;
        let hd = d / h;
        let m = bsz * t;
        ensure!(input_ids.len() == m, "input ids length mismatch");

        let tok_emb = params.get("embed.tok")?;
        let pos_emb = params.get("embed.pos")?;
        let vocab = self.dims.vocab;
        let mut x = Tensor::zeros(&[m, d]);
        for (row, &id) in input_ids.iter().enumerate() {
            ensure!((id as usize) < vocab, "token id {id} out of vocab {vocab}");
            let tpos = row % t;
            let dst = &mut x.data[row * d..(row + 1) * d];
            let te = &tok_emb.data[id as usize * d..(id as usize + 1) * d];
            let pe = &pos_emb.data[tpos * d..(tpos + 1) * d];
            for j in 0..d {
                dst[j] = te[j] + pe[j];
            }
        }

        let mut layers = Vec::with_capacity(self.dims.n_layers);
        for i in 0..self.dims.n_layers {
            let pre = format!("layers.{i}");
            let xin = x.clone();
            let g1 = params.get(&format!("{pre}.attn.norm"))?;
            let (xn1, r1) = rmsnorm_fwd(&xin, &g1.data);
            let (q, cq) = self.linear_fwd(params, &format!("{pre}.attn.wq"), &xn1)?;
            let (k, ck) = self.linear_fwd(params, &format!("{pre}.attn.wk"), &xn1)?;
            let (v, cv) = self.linear_fwd(params, &format!("{pre}.attn.wv"), &xn1)?;
            let (o, att) = attention_fwd(&q, &k, &v, bsz, t, h, hd);
            let (ywo, co) = self.linear_fwd(params, &format!("{pre}.attn.wo"), &o)?;
            let x_mid = xin.add(&ywo)?;
            let g2 = params.get(&format!("{pre}.mlp.norm"))?;
            let (xn2, r2) = rmsnorm_fwd(&x_mid, &g2.data);
            let (up_pre, cup) = self.linear_fwd(params, &format!("{pre}.mlp.up"), &xn2)?;
            let act = gelu_fwd(&up_pre);
            let (ydown, cdown) = self.linear_fwd(params, &format!("{pre}.mlp.down"), &act)?;
            x = x_mid.add(&ydown)?;
            layers.push(LayerFwd {
                xin,
                r1,
                cq,
                ck,
                cv,
                q,
                k,
                v,
                att,
                co,
                x_mid,
                r2,
                cup,
                up_pre,
                cdown,
            });
        }

        let gf = params.get("final_norm")?;
        let (xf, rf) = rmsnorm_fwd(&x, &gf.data);
        let head = params.get("lm_head")?;
        let logits = xf.matmul(head)?;
        Ok(Fwd {
            bsz,
            input_ids: input_ids.to_vec(),
            x_final: x,
            rf,
            xf,
            logits,
            layers,
        })
    }

    fn linear_fwd(&self, params: &Params, name: &str, x: &Tensor) -> Result<(Tensor, LinCache)> {
        let w = params.get(name)?.clone();
        let mut cache = LinCache {
            name: name.to_string(),
            x: x.clone(),
            w,
            lora: None,
            oft: None,
            rw: None,
        };
        let y = match self.method {
            Method::Lora | Method::QLora => {
                let a = params.get(&format!("{name}.lora_a"))?.clone();
                let b = params.get(&format!("{name}.lora_b"))?.clone();
                let scale = (self.dims.lora_alpha / self.dims.lora_r as f64) as f32;
                let xa = x.matmul(&a)?;
                let y = x.matmul(&cache.w)?.add(&xa.matmul(&b)?.scale(scale))?;
                cache.lora = Some(LoraCache { a, b, xa, scale });
                y
            }
            Method::OftV2 | Method::QOft => {
                let packed = params.get(&format!("{name}.oft_q"))?.clone();
                let blocks = build_cnp_blocks(&packed, self.dims.block_b, self.dims.neumann_k)?;
                let z = block_rotate_fast(x, &blocks)?;
                let y = z.matmul(&cache.w)?;
                cache.oft = Some(OftCache { packed, blocks });
                y
            }
            Method::OftMerged => {
                let packed = params.get(&format!("{name}.oft_q"))?.clone();
                let blocks = build_cnp_blocks(&packed, self.dims.block_b, self.dims.neumann_k)?;
                // The weight-centric baseline: materialize blockdiag(R)
                // and pay the cubic matrix-matrix merge every forward.
                let rd = peft::blockdiag_dense(&blocks, cache.w.shape[0]);
                let rw = rd.matmul(&cache.w)?;
                let y = x.matmul(&rw)?;
                cache.oft = Some(OftCache { packed, blocks });
                cache.rw = Some(rw);
                y
            }
            Method::Full | Method::None => x.matmul(&cache.w)?,
        };
        Ok((y, cache))
    }

    // -----------------------------------------------------------------
    // Backward
    // -----------------------------------------------------------------

    /// Mean masked NLL and gradients for every trainable parameter.
    pub fn loss_and_grads(
        &self,
        params: &Params,
        tokens: &[i32],
        mask: &[f32],
    ) -> Result<(f32, BTreeMap<String, Tensor>)> {
        let (bsz, t) = (self.dims.batch, self.dims.seq_len);
        ensure!(tokens.len() == bsz * (t + 1), "tokens shape mismatch");
        ensure!(mask.len() == bsz * t, "mask shape mismatch");
        self.validate_token_ids(tokens)?;
        let (input_ids, targets) = split_tokens(tokens, bsz, t);
        let fwd = self.forward(params, &input_ids, bsz)?;

        let v = self.dims.vocab;
        let m = bsz * t;
        let (sum_nll, raw_count, logp) = nll_stats(&fwd.logits, &targets, mask);
        let count = raw_count.max(1.0);
        let loss = sum_nll / count;

        // d(loss)/d(logits) = (softmax - onehot) * mask / count
        let mut dlogits = Tensor::zeros(&[m, v]);
        for row in 0..m {
            let scale = mask[row] / count;
            if scale == 0.0 {
                continue;
            }
            let lp = &logp.data[row * v..(row + 1) * v];
            let dl = &mut dlogits.data[row * v..(row + 1) * v];
            for j in 0..v {
                dl[j] = lp[j].exp() * scale;
            }
            dl[targets[row] as usize] -= scale;
        }

        let grads = self.backward(params, &fwd, &dlogits)?;
        Ok((loss, grads))
    }

    fn backward(
        &self,
        params: &Params,
        fwd: &Fwd,
        dlogits: &Tensor,
    ) -> Result<BTreeMap<String, Tensor>> {
        let full = self.method == Method::Full;
        let (bsz, t) = (fwd.bsz, self.dims.seq_len);
        let d = self.dims.d_model;
        let h = self.dims.n_heads;
        let hd = d / h;
        let mut grads: BTreeMap<String, Tensor> = BTreeMap::new();

        let head = params.get("lm_head")?;
        if full {
            accumulate(&mut grads, "lm_head", fwd.xf.transpose2().matmul(dlogits)?);
        }
        let dxf = dlogits.matmul(&head.transpose2())?;
        let gf = params.get("final_norm")?;
        let (mut dx, dgf) = rmsnorm_bwd(&fwd.x_final, &gf.data, &fwd.rf, &dxf);
        if full {
            accumulate(&mut grads, "final_norm", dgf);
        }

        for i in (0..self.dims.n_layers).rev() {
            let pre = format!("layers.{i}");
            let c = &fwd.layers[i];
            let dact = self.linear_bwd(&c.cdown, &dx, &mut grads)?;
            let dup = gelu_bwd(&c.up_pre, &dact);
            let dxn2 = self.linear_bwd(&c.cup, &dup, &mut grads)?;
            let g2 = params.get(&format!("{pre}.mlp.norm"))?;
            let (dxmid_n, dg2) = rmsnorm_bwd(&c.x_mid, &g2.data, &c.r2, &dxn2);
            if full {
                accumulate(&mut grads, &format!("{pre}.mlp.norm"), dg2);
            }
            let dxmid = dx.add(&dxmid_n)?;
            let do_ = self.linear_bwd(&c.co, &dxmid, &mut grads)?;
            let (dq, dk, dv) = attention_bwd(&c.q, &c.k, &c.v, &c.att, &do_, bsz, t, h, hd);
            let dxn1 = self
                .linear_bwd(&c.cq, &dq, &mut grads)?
                .add(&self.linear_bwd(&c.ck, &dk, &mut grads)?)?
                .add(&self.linear_bwd(&c.cv, &dv, &mut grads)?)?;
            let g1 = params.get(&format!("{pre}.attn.norm"))?;
            let (dxin_n, dg1) = rmsnorm_bwd(&c.xin, &g1.data, &c.r1, &dxn1);
            if full {
                accumulate(&mut grads, &format!("{pre}.attn.norm"), dg1);
            }
            dx = dxmid.add(&dxin_n)?;
        }

        if full {
            let vocab = self.dims.vocab;
            let mut dtok = Tensor::zeros(&[vocab, d]);
            let mut dpos = Tensor::zeros(&[t, d]);
            for (row, &id) in fwd.input_ids.iter().enumerate() {
                let tpos = row % t;
                let src = &dx.data[row * d..(row + 1) * d];
                let te = &mut dtok.data[id as usize * d..(id as usize + 1) * d];
                for j in 0..d {
                    te[j] += src[j];
                }
                let pe = &mut dpos.data[tpos * d..(tpos + 1) * d];
                for j in 0..d {
                    pe[j] += src[j];
                }
            }
            accumulate(&mut grads, "embed.tok", dtok);
            accumulate(&mut grads, "embed.pos", dpos);
        }
        Ok(grads)
    }

    /// Backward of one adapted linear: accumulates parameter grads and
    /// returns d(loss)/d(input).
    fn linear_bwd(
        &self,
        c: &LinCache,
        dy: &Tensor,
        grads: &mut BTreeMap<String, Tensor>,
    ) -> Result<Tensor> {
        let b = self.dims.block_b;
        match self.method {
            Method::Full => {
                accumulate(grads, &c.name, c.x.transpose2().matmul(dy)?);
                dy.matmul(&c.w.transpose2())
            }
            Method::None => dy.matmul(&c.w.transpose2()),
            Method::Lora | Method::QLora => {
                let lc = c.lora.as_ref().context("missing lora cache")?;
                let dxa = dy.matmul(&lc.b.transpose2())?.scale(lc.scale);
                accumulate(
                    grads,
                    &format!("{}.lora_b", c.name),
                    lc.xa.transpose2().matmul(dy)?.scale(lc.scale),
                );
                accumulate(
                    grads,
                    &format!("{}.lora_a", c.name),
                    c.x.transpose2().matmul(&dxa)?,
                );
                dy.matmul(&c.w.transpose2())?.add(&dxa.matmul(&lc.a.transpose2())?)
            }
            Method::OftV2 | Method::QOft => {
                let oc = c.oft.as_ref().context("missing oft cache")?;
                let dz = dy.matmul(&c.w.transpose2())?;
                let dr = block_rotate_grad_r(&c.x, &dz, b);
                let dp = cnp_backward_all(&oc.packed, b, self.dims.neumann_k, &dr)?;
                accumulate(grads, &format!("{}.oft_q", c.name), dp);
                block_rotate_transposed(&dz, &oc.blocks)
            }
            Method::OftMerged => {
                let oc = c.oft.as_ref().context("missing oft cache")?;
                let rw = c.rw.as_ref().context("missing merged weight cache")?;
                let dm = c.x.transpose2().matmul(dy)?; // (din, dout)
                let din = c.w.shape[0];
                let nb = din / b;
                let dout = c.w.shape[1];
                let mut dr = Vec::with_capacity(nb);
                for bi in 0..nb {
                    let dm_b = Tensor::from_vec(
                        &[b, dout],
                        dm.data[bi * b * dout..(bi + 1) * b * dout].to_vec(),
                    );
                    let w_b = Tensor::from_vec(
                        &[b, dout],
                        c.w.data[bi * b * dout..(bi + 1) * b * dout].to_vec(),
                    );
                    dr.push(dm_b.matmul(&w_b.transpose2())?);
                }
                let dp = cnp_backward_all(&oc.packed, b, self.dims.neumann_k, &dr)?;
                accumulate(grads, &format!("{}.oft_q", c.name), dp);
                dy.matmul(&rw.transpose2())
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Incremental (KV-cached) decoding
// ---------------------------------------------------------------------------

/// One adapted linear with the adapter resolved at build time: decode
/// steps pay only the per-token apply, never dequantization or CNP
/// block construction.
enum DecLinear {
    Plain { w: Tensor },
    Lora { w: Tensor, a: Tensor, b: Tensor, scale: f32 },
    /// Input-centric OFTv2/QOFT: rotate the token's activations
    /// block-by-block, then the frozen matmul (matrix-free, §3).
    Rotate { w: Tensor, blocks: Vec<Tensor> },
    /// Weight-centric baseline: blockdiag(R) @ W merged once at load
    /// (decoding re-pays it per adapter, not per token).
    Merged { rw: Tensor },
}

impl DecLinear {
    /// Apply to a (1, din) row; mirrors `linear_fwd` operation order so
    /// decode logits match the full re-forward bit for bit.
    fn apply(&self, x: &Tensor) -> Result<Tensor> {
        match self {
            DecLinear::Plain { w } => x.matmul(w),
            DecLinear::Lora { w, a, b, scale } => {
                let xa = x.matmul(a)?;
                x.matmul(w)?.add(&xa.matmul(b)?.scale(*scale))
            }
            DecLinear::Rotate { w, blocks } => block_rotate_fast(x, blocks)?.matmul(w),
            DecLinear::Merged { rw } => x.matmul(rw),
        }
    }
}

struct DecLayer {
    attn_norm: Vec<f32>,
    wq: DecLinear,
    wk: DecLinear,
    wv: DecLinear,
    wo: DecLinear,
    mlp_norm: Vec<f32>,
    up: DecLinear,
    down: DecLinear,
}

/// Per-sequence KV cache: one (seq_len, d_model) key and value plane
/// per layer, filled left to right.
pub struct KvCache {
    /// Interleaved per layer: k then v, each seq_len * d_model.
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    len: usize,
}

impl KvCache {
    pub fn position(&self) -> usize {
        self.len
    }
}

/// A bundle + adapter state compiled for incremental decoding: token
/// step cost is O(T) in cache length instead of the O(T²) full
/// re-forward `logits_last` pays per generated token.
pub struct DecodeModel {
    dims: ModelDims,
    tok_emb: Tensor,
    pos_emb: Tensor,
    final_norm: Vec<f32>,
    lm_head: Tensor,
    layers: Vec<DecLayer>,
}

impl RefBundle {
    /// Resolve trainables + fixed inputs into a [`DecodeModel`] —
    /// dequantization and adapter merging happen here, once.
    pub fn decode_model(&self, trainables: &[&Value], fixed: &[&Value]) -> Result<DecodeModel> {
        let params = self.assemble_params(trainables, fixed)?;
        let norm = |name: &str| -> Result<Vec<f32>> { Ok(params.get(name)?.data.clone()) };
        let linear = |name: &str| -> Result<DecLinear> { self.resolve_linear(&params, name) };
        let mut layers = Vec::with_capacity(self.dims.n_layers);
        for i in 0..self.dims.n_layers {
            let pre = format!("layers.{i}");
            layers.push(DecLayer {
                attn_norm: norm(&format!("{pre}.attn.norm"))?,
                wq: linear(&format!("{pre}.attn.wq"))?,
                wk: linear(&format!("{pre}.attn.wk"))?,
                wv: linear(&format!("{pre}.attn.wv"))?,
                wo: linear(&format!("{pre}.attn.wo"))?,
                mlp_norm: norm(&format!("{pre}.mlp.norm"))?,
                up: linear(&format!("{pre}.mlp.up"))?,
                down: linear(&format!("{pre}.mlp.down"))?,
            });
        }
        Ok(DecodeModel {
            dims: self.dims,
            tok_emb: params.get("embed.tok")?.clone(),
            pos_emb: params.get("embed.pos")?.clone(),
            final_norm: norm("final_norm")?,
            lm_head: params.get("lm_head")?.clone(),
            layers,
        })
    }

    fn resolve_linear(&self, params: &Params, name: &str) -> Result<DecLinear> {
        let w = params.get(name)?.clone();
        Ok(match self.method {
            Method::Full | Method::None => DecLinear::Plain { w },
            Method::Lora | Method::QLora => DecLinear::Lora {
                a: params.get(&format!("{name}.lora_a"))?.clone(),
                b: params.get(&format!("{name}.lora_b"))?.clone(),
                scale: (self.dims.lora_alpha / self.dims.lora_r as f64) as f32,
                w,
            },
            Method::OftV2 | Method::QOft => {
                let packed = params.get(&format!("{name}.oft_q"))?;
                let blocks = build_cnp_blocks(packed, self.dims.block_b, self.dims.neumann_k)?;
                DecLinear::Rotate { w, blocks }
            }
            Method::OftMerged => {
                let packed = params.get(&format!("{name}.oft_q"))?;
                let blocks = build_cnp_blocks(packed, self.dims.block_b, self.dims.neumann_k)?;
                let rd = peft::blockdiag_dense(&blocks, w.shape[0]);
                DecLinear::Merged { rw: rd.matmul(&w)? }
            }
        })
    }
}

impl DecodeModel {
    pub fn seq_len(&self) -> usize {
        self.dims.seq_len
    }

    pub fn vocab(&self) -> usize {
        self.dims.vocab
    }

    /// Empty cache sized for one sequence.
    pub fn new_cache(&self) -> KvCache {
        let plane = self.dims.seq_len * self.dims.d_model;
        KvCache {
            k: (0..self.dims.n_layers).map(|_| vec![0f32; plane]).collect(),
            v: (0..self.dims.n_layers).map(|_| vec![0f32; plane]).collect(),
            len: 0,
        }
    }

    /// Incremental forward: consume `token` at position `cache.len`
    /// and return the next-token logits (V,). Only the new token's
    /// activations are computed (and, for OFTv2/QOFT, rotated) —
    /// attention reads keys/values from the per-sequence cache, so a
    /// T-token greedy decode is O(T) forwards of one row instead of
    /// the O(T²) whole-sequence re-forwards `logits_last` pays.
    pub fn forward_incremental(&self, cache: &mut KvCache, token: i32) -> Result<Vec<f32>> {
        let d = self.dims.d_model;
        let t = self.dims.seq_len;
        let h = self.dims.n_heads;
        let hd = d / h;
        let pos = cache.len;
        ensure!(pos < t, "KV cache full: position {pos} of seq_len {t}");
        ensure!(
            token >= 0 && (token as usize) < self.dims.vocab,
            "token id {token} out of vocab {}",
            self.dims.vocab
        );

        let mut x = Tensor::zeros(&[1, d]);
        {
            let te = &self.tok_emb.data[token as usize * d..(token as usize + 1) * d];
            let pe = &self.pos_emb.data[pos * d..(pos + 1) * d];
            for j in 0..d {
                x.data[j] = te[j] + pe[j];
            }
        }

        for (li, layer) in self.layers.iter().enumerate() {
            let (xn1, _) = rmsnorm_fwd(&x, &layer.attn_norm);
            let q = layer.wq.apply(&xn1)?;
            let k = layer.wk.apply(&xn1)?;
            let v = layer.wv.apply(&xn1)?;
            cache.k[li][pos * d..(pos + 1) * d].copy_from_slice(&k.data);
            cache.v[li][pos * d..(pos + 1) * d].copy_from_slice(&v.data);

            // Single-query causal attention over the cache; loop order
            // mirrors attention_fwd so results match bitwise.
            let scale = 1.0 / (hd as f32).sqrt();
            let mut o = Tensor::zeros(&[1, d]);
            for hh in 0..h {
                let qoff = hh * hd;
                let mut row = vec![0f32; pos + 1];
                let mut maxv = f32::NEG_INFINITY;
                for (t2, rv) in row.iter_mut().enumerate() {
                    let koff = t2 * d + hh * hd;
                    let mut acc = 0f32;
                    for c in 0..hd {
                        acc += q.data[qoff + c] * cache.k[li][koff + c];
                    }
                    *rv = acc * scale;
                    maxv = maxv.max(*rv);
                }
                let mut sum = 0f32;
                for rv in &mut row {
                    *rv = (*rv - maxv).exp();
                    sum += *rv;
                }
                for (t2, rv) in row.iter().enumerate() {
                    let a = rv / sum;
                    let voff = t2 * d + hh * hd;
                    for c in 0..hd {
                        o.data[qoff + c] += a * cache.v[li][voff + c];
                    }
                }
            }

            let ywo = layer.wo.apply(&o)?;
            let x_mid = x.add(&ywo)?;
            let (xn2, _) = rmsnorm_fwd(&x_mid, &layer.mlp_norm);
            let up_pre = layer.up.apply(&xn2)?;
            let act = gelu_fwd(&up_pre);
            let ydown = layer.down.apply(&act)?;
            x = x_mid.add(&ydown)?;
        }

        cache.len = pos + 1;
        let (xf, _) = rmsnorm_fwd(&x, &self.final_norm);
        let logits = xf.matmul(&self.lm_head)?;
        Ok(logits.data)
    }
}

/// Name-keyed parameter map (trainables + frozen + dequantized bases).
pub struct Params {
    pub map: BTreeMap<String, Tensor>,
}

impl Params {
    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.map
            .get(name)
            .with_context(|| format!("missing parameter '{name}'"))
    }
}

struct LoraCache {
    a: Tensor,
    b: Tensor,
    xa: Tensor,
    scale: f32,
}

struct OftCache {
    packed: Tensor,
    blocks: Vec<Tensor>,
}

struct LinCache {
    name: String,
    x: Tensor,
    w: Tensor,
    lora: Option<LoraCache>,
    oft: Option<OftCache>,
    rw: Option<Tensor>,
}

struct LayerFwd {
    xin: Tensor,
    r1: Vec<f32>,
    cq: LinCache,
    ck: LinCache,
    cv: LinCache,
    q: Tensor,
    k: Tensor,
    v: Tensor,
    /// Softmax probabilities, (bsz, heads, T, T) flattened.
    att: Vec<f32>,
    co: LinCache,
    x_mid: Tensor,
    r2: Vec<f32>,
    cup: LinCache,
    up_pre: Tensor,
    cdown: LinCache,
}

struct Fwd {
    bsz: usize,
    input_ids: Vec<i32>,
    /// Input to the final norm (M, D).
    x_final: Tensor,
    rf: Vec<f32>,
    /// Final-normed activations (M, D).
    xf: Tensor,
    /// (M, V).
    logits: Tensor,
    layers: Vec<LayerFwd>,
}

// ---------------------------------------------------------------------------
// Shared kernels (also used by the reference engine's micro kernels)
// ---------------------------------------------------------------------------

fn value_tensor(v: &Value, shape: &[usize]) -> Result<Tensor> {
    let data = v.f32s()?;
    ensure!(
        data.len() == shape.iter().product::<usize>(),
        "input has {} elements, shape {shape:?} wants {}",
        data.len(),
        shape.iter().product::<usize>()
    );
    Ok(Tensor::from_vec(shape, data.to_vec()))
}

fn split_tokens(tokens: &[i32], bsz: usize, t: usize) -> (Vec<i32>, Vec<i32>) {
    let mut inputs = Vec::with_capacity(bsz * t);
    let mut targets = Vec::with_capacity(bsz * t);
    for b in 0..bsz {
        let row = &tokens[b * (t + 1)..(b + 1) * (t + 1)];
        inputs.extend_from_slice(&row[..t]);
        targets.extend_from_slice(&row[1..]);
    }
    (inputs, targets)
}

/// Per-row NLL over masked targets: returns (sum_nll, mask_count, logp).
fn nll_stats(logits: &Tensor, targets: &[i32], mask: &[f32]) -> (f32, f32, Tensor) {
    let m = logits.shape[0];
    let v = logits.shape[1];
    let mut logp = Tensor::zeros(&[m, v]);
    let mut sum_nll = 0f32;
    let mut count = 0f32;
    for row in 0..m {
        let lr = &logits.data[row * v..(row + 1) * v];
        let maxv = lr.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x));
        let mut sum = 0f32;
        for &x in lr {
            sum += (x - maxv).exp();
        }
        let lse = maxv + sum.ln();
        let out = &mut logp.data[row * v..(row + 1) * v];
        for j in 0..v {
            out[j] = lr[j] - lse;
        }
        sum_nll += -out[targets[row] as usize] * mask[row];
        count += mask[row];
    }
    (sum_nll, count, logp)
}

/// Build all CNP blocks R_i = (I+Q_i)(I + sum Q_i^j) from packed rows.
pub fn build_cnp_blocks(packed: &Tensor, b: usize, k: usize) -> Result<Vec<Tensor>> {
    let p = peft::packed_dim(b);
    ensure!(
        packed.shape.len() == 2 && packed.shape[1] == p,
        "packed Q must be (nb, {p}), got {:?}",
        packed.shape
    );
    let nb = packed.shape[0];
    let mut out = Vec::with_capacity(nb);
    for i in 0..nb {
        out.push(peft::cayley_neumann(&packed.data[i * p..(i + 1) * p], b, k)?);
    }
    Ok(out)
}

/// Fused block rotation y[:, ib:(i+1)b] = x[:, ib:(i+1)b] @ R_i — one
/// pass over x, parallel over rows (the OFTv2 hot path).
pub fn block_rotate_fast(x: &Tensor, blocks: &[Tensor]) -> Result<Tensor> {
    ensure!(x.rank() == 2, "block_rotate_fast needs 2-D input");
    let (m, d) = (x.shape[0], x.shape[1]);
    ensure!(!blocks.is_empty(), "no rotation blocks");
    let b = blocks[0].shape[0];
    ensure!(blocks.len() * b == d, "blocks {}x{b} vs d={d}", blocks.len());
    let mut out = vec![0f32; m * d];
    crate::tensor::parallel_over_rows(&mut out, m, d, |row, dst| {
        let src = &x.data[row * d..(row + 1) * d];
        for (bi, blk) in blocks.iter().enumerate() {
            let xoff = bi * b;
            for j in 0..b {
                let mut acc = 0f32;
                for i in 0..b {
                    acc += src[xoff + i] * blk.data[i * b + j];
                }
                dst[xoff + j] = acc;
            }
        }
    });
    Ok(Tensor::from_vec(&[m, d], out))
}

/// Rotate by the transposed blocks (the backward direction dz @ R^T).
fn block_rotate_transposed(dz: &Tensor, blocks: &[Tensor]) -> Result<Tensor> {
    let (m, d) = (dz.shape[0], dz.shape[1]);
    let b = blocks[0].shape[0];
    ensure!(blocks.len() * b == d, "blocks {}x{b} vs d={d}", blocks.len());
    let mut out = vec![0f32; m * d];
    crate::tensor::parallel_over_rows(&mut out, m, d, |row, dst| {
        let src = &dz.data[row * d..(row + 1) * d];
        for (bi, blk) in blocks.iter().enumerate() {
            let off = bi * b;
            for i in 0..b {
                let mut acc = 0f32;
                for j in 0..b {
                    acc += src[off + j] * blk.data[i * b + j];
                }
                dst[off + i] = acc;
            }
        }
    });
    Ok(Tensor::from_vec(&[m, d], out))
}

/// dR_i = x_i^T @ dz_i summed over rows; returns one (b, b) per block.
fn block_rotate_grad_r(x: &Tensor, dz: &Tensor, b: usize) -> Vec<Tensor> {
    let (m, d) = (x.shape[0], x.shape[1]);
    let nb = d / b;
    let mut dr: Vec<Tensor> = (0..nb).map(|_| Tensor::zeros(&[b, b])).collect();
    for row in 0..m {
        let xr = &x.data[row * d..(row + 1) * d];
        let dzr = &dz.data[row * d..(row + 1) * d];
        for (bi, g) in dr.iter_mut().enumerate() {
            let off = bi * b;
            for i in 0..b {
                let xi = xr[off + i];
                if xi == 0.0 {
                    continue;
                }
                let grow = &mut g.data[i * b..(i + 1) * b];
                for j in 0..b {
                    grow[j] += xi * dzr[off + j];
                }
            }
        }
    }
    dr
}

/// d(loss)/d(packed) for one CNP block, given G = d(loss)/dR.
///
/// R = (I+Q) S with S = sum_{i=0..k} Q^i:
///   dQ = G S^T + sum_{i=1..k} sum_{j=0..i-1} (Q^T)^j H (Q^T)^{i-1-j},
/// with H = (I+Q)^T G; then project onto the packed skew coordinates
/// (dp_ij = dQ_ij - dQ_ji for i < j). Locked against jax.grad by
/// python/tests/test_ref_backward.py::test_cnp_backward_matches_jax.
pub fn cnp_backward(packed: &[f32], b: usize, k: usize, g: &Tensor) -> Result<Vec<f32>> {
    let q = peft::skew_from_packed(packed, b);
    let eye = Tensor::eye(b);
    let mut acc = eye.clone();
    let mut term = eye.clone();
    for _ in 0..k {
        term = term.matmul(&q)?;
        acc = acc.add(&term)?;
    }
    let mut dq = g.matmul(&acc.transpose2())?;
    let h = eye.add(&q)?.transpose2().matmul(g)?;
    let qt = q.transpose2();
    let mut powers = vec![eye];
    for _ in 1..k.max(1) {
        let next = powers.last().unwrap().matmul(&qt)?;
        powers.push(next);
    }
    for i in 1..=k {
        for j in 0..i {
            let t = powers[j].matmul(&h)?.matmul(&powers[i - 1 - j])?;
            dq = dq.add(&t)?;
        }
    }
    let mut dp = vec![0f32; peft::packed_dim(b)];
    let mut idx = 0;
    for i in 0..b {
        for j in i + 1..b {
            dp[idx] = dq.at2(i, j) - dq.at2(j, i);
            idx += 1;
        }
    }
    Ok(dp)
}

/// CNP backward over all blocks; returns the (nb, p) packed gradient.
fn cnp_backward_all(packed: &Tensor, b: usize, k: usize, dr: &[Tensor]) -> Result<Tensor> {
    let p = peft::packed_dim(b);
    let nb = packed.shape[0];
    ensure!(dr.len() == nb, "expected {nb} block grads, got {}", dr.len());
    let mut out = vec![0f32; nb * p];
    for i in 0..nb {
        let dp = cnp_backward(&packed.data[i * p..(i + 1) * p], b, k, &dr[i])?;
        out[i * p..(i + 1) * p].copy_from_slice(&dp);
    }
    Ok(Tensor::from_vec(&[nb, p], out))
}

/// RMSNorm forward: y = x * rsqrt(mean(x^2) + 1e-6) * g. Returns the
/// per-row rsqrt factors for the backward pass.
fn rmsnorm_fwd(x: &Tensor, g: &[f32]) -> (Tensor, Vec<f32>) {
    let (m, d) = (x.shape[0], x.shape[1]);
    let mut y = Tensor::zeros(&[m, d]);
    let mut rs = vec![0f32; m];
    for row in 0..m {
        let xr = &x.data[row * d..(row + 1) * d];
        let mut s = 0f32;
        for &v in xr {
            s += v * v;
        }
        let r = 1.0 / (s / d as f32 + 1e-6).sqrt();
        rs[row] = r;
        let yr = &mut y.data[row * d..(row + 1) * d];
        for j in 0..d {
            yr[j] = xr[j] * r * g[j];
        }
    }
    (y, rs)
}

/// RMSNorm backward: returns (dx, dg).
fn rmsnorm_bwd(x: &Tensor, g: &[f32], r: &[f32], dy: &Tensor) -> (Tensor, Tensor) {
    let (m, d) = (x.shape[0], x.shape[1]);
    let mut dx = Tensor::zeros(&[m, d]);
    let mut dg = Tensor::zeros(&[d]);
    for row in 0..m {
        let xr = &x.data[row * d..(row + 1) * d];
        let dyr = &dy.data[row * d..(row + 1) * d];
        let rr = r[row];
        let mut s = 0f32;
        for j in 0..d {
            s += dyr[j] * g[j] * xr[j];
            dg.data[j] += dyr[j] * xr[j] * rr;
        }
        let f = rr * rr * rr / d as f32 * s;
        let dxr = &mut dx.data[row * d..(row + 1) * d];
        for j in 0..d {
            dxr[j] = dyr[j] * g[j] * rr - xr[j] * f;
        }
    }
    (dx, dg)
}

const GELU_C: f32 = 0.797_884_56; // sqrt(2/pi)
const GELU_A: f32 = 0.044715;

/// Tanh-approximate GELU (JAX's default `approximate=True`).
fn gelu_fwd(x: &Tensor) -> Tensor {
    let mut y = x.clone();
    for v in &mut y.data {
        let u = GELU_C * (*v + GELU_A * *v * *v * *v);
        *v = 0.5 * *v * (1.0 + u.tanh());
    }
    y
}

fn gelu_bwd(x: &Tensor, dy: &Tensor) -> Tensor {
    let mut dx = x.clone();
    for (v, &dyv) in dx.data.iter_mut().zip(&dy.data) {
        let xv = *v;
        let u = GELU_C * (xv + GELU_A * xv * xv * xv);
        let th = u.tanh();
        *v = dyv
            * (0.5 * (1.0 + th)
                + 0.5 * xv * (1.0 - th * th) * GELU_C * (1.0 + 3.0 * GELU_A * xv * xv));
    }
    dx
}

/// Causal multi-head attention forward. Returns (output (M, D), softmax
/// probabilities (bsz*h*t*t, future positions exactly zero)).
fn attention_fwd(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    bsz: usize,
    t: usize,
    h: usize,
    hd: usize,
) -> (Tensor, Vec<f32>) {
    let d = h * hd;
    let m = bsz * t;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut att = vec![0f32; bsz * h * t * t];
    let mut o = Tensor::zeros(&[m, d]);
    for b in 0..bsz {
        for hh in 0..h {
            for t1 in 0..t {
                let qoff = (b * t + t1) * d + hh * hd;
                let mut row = vec![0f32; t1 + 1];
                let mut maxv = f32::NEG_INFINITY;
                for (t2, rv) in row.iter_mut().enumerate() {
                    let koff = (b * t + t2) * d + hh * hd;
                    let mut acc = 0f32;
                    for c in 0..hd {
                        acc += q.data[qoff + c] * k.data[koff + c];
                    }
                    *rv = acc * scale;
                    maxv = maxv.max(*rv);
                }
                let mut sum = 0f32;
                for rv in &mut row {
                    *rv = (*rv - maxv).exp();
                    sum += *rv;
                }
                let abase = ((b * h + hh) * t + t1) * t;
                let ooff = (b * t + t1) * d + hh * hd;
                for (t2, rv) in row.iter().enumerate() {
                    let a = rv / sum;
                    att[abase + t2] = a;
                    let voff = (b * t + t2) * d + hh * hd;
                    for c in 0..hd {
                        o.data[ooff + c] += a * v.data[voff + c];
                    }
                }
            }
        }
    }
    (o, att)
}

/// Causal attention backward: returns (dq, dk, dv).
fn attention_bwd(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    att: &[f32],
    do_: &Tensor,
    bsz: usize,
    t: usize,
    h: usize,
    hd: usize,
) -> (Tensor, Tensor, Tensor) {
    let d = h * hd;
    let m = bsz * t;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut dq = Tensor::zeros(&[m, d]);
    let mut dk = Tensor::zeros(&[m, d]);
    let mut dv = Tensor::zeros(&[m, d]);
    for b in 0..bsz {
        for hh in 0..h {
            for t1 in 0..t {
                let abase = ((b * h + hh) * t + t1) * t;
                let ooff = (b * t + t1) * d + hh * hd;
                let mut dpost = vec![0f32; t1 + 1];
                for (t2, dp) in dpost.iter_mut().enumerate() {
                    let voff = (b * t + t2) * d + hh * hd;
                    let a = att[abase + t2];
                    let mut acc = 0f32;
                    for c in 0..hd {
                        let g = do_.data[ooff + c];
                        acc += g * v.data[voff + c];
                        dv.data[voff + c] += a * g;
                    }
                    *dp = acc;
                }
                let mut dot = 0f32;
                for (t2, dp) in dpost.iter().enumerate() {
                    dot += dp * att[abase + t2];
                }
                let qoff = ooff;
                for (t2, dp) in dpost.iter().enumerate() {
                    let da = att[abase + t2] * (dp - dot) * scale;
                    if da == 0.0 {
                        continue;
                    }
                    let koff = (b * t + t2) * d + hh * hd;
                    for c in 0..hd {
                        dq.data[qoff + c] += da * k.data[koff + c];
                        dk.data[koff + c] += da * q.data[qoff + c];
                    }
                }
            }
        }
    }
    (dq, dk, dv)
}

fn accumulate(grads: &mut BTreeMap<String, Tensor>, name: &str, g: Tensor) {
    match grads.get_mut(name) {
        Some(t) => {
            for (a, b) in t.data.iter_mut().zip(&g.data) {
                *a += b;
            }
        }
        None => {
            grads.insert(name.to_string(), g);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::manifest::Manifest;
    use crate::util::rng::Rng;

    fn bundle(tag: &str) -> RefBundle {
        RefBundle::from_manifest(&Manifest::builtin(tag).unwrap()).unwrap()
    }

    fn random_values(specs: &[ParamSpec], std: f32, seed: u64) -> Vec<Value> {
        let mut rng = Rng::new(seed);
        specs
            .iter()
            .map(|s| lit_f32(&s.shape, &rng.normal_vec(s.numel(), std)).unwrap())
            .collect()
    }

    fn batch(bu: &RefBundle, seed: u64) -> (Value, Value) {
        let (b, t) = (bu.dims.batch, bu.dims.seq_len);
        let mut rng = Rng::new(seed);
        let toks: Vec<i32> = (0..b * (t + 1))
            .map(|_| rng.below(bu.dims.vocab) as i32)
            .collect();
        let mask = vec![1.0f32; b * t];
        (
            super::super::lit_i32(&[b, t + 1], &toks).unwrap(),
            lit_f32(&[b, t], &mask).unwrap(),
        )
    }

    /// Run train_step at lr=0 (returns pre-update loss; new_m encodes
    /// the raw gradient as new_m = (1-b1) g when m starts at zero).
    fn step_outputs(bu: &RefBundle, tr: &[Value], toks: &Value, mask: &Value) -> Vec<Value> {
        let n = tr.len();
        let zeros: Vec<Value> = bu
            .trainable
            .iter()
            .map(|s| lit_f32(&s.shape, &vec![0.0; s.numel()]).unwrap())
            .collect();
        // realistic frozen base (norms at 1, weights ~N(0, 0.02)) so
        // gradient magnitudes are representative
        let fixed: Vec<Value> = bu
            .frozen
            .iter()
            .map(|s| {
                let t = crate::coordinator::state::init_param(s, 99, None).unwrap();
                lit_f32(&s.shape, &t.data).unwrap()
            })
            .collect();
        let mut inputs: Vec<&Value> = Vec::new();
        inputs.extend(tr.iter());
        inputs.extend(zeros.iter());
        inputs.extend(zeros.iter());
        inputs.extend(fixed.iter());
        let lr = super::super::lit_scalar_f32(0.0);
        let t1 = super::super::lit_scalar_f32(1.0);
        inputs.push(toks);
        inputs.push(mask);
        inputs.push(&lr);
        inputs.push(&t1);
        let out = bu.train_step(&inputs).unwrap();
        assert_eq!(out.len(), 3 * n + 1);
        out
    }

    #[test]
    fn train_step_gradients_match_finite_differences() {
        // tiny_oft_v2 with non-trivial Q; gradient recovered from the
        // first Adam moment at m0 = 0: new_m = (1 - b1) g.
        let bu = bundle("tiny_oft_v2");
        let n = bu.n_trainable();
        let tr = random_values(&bu.trainable, 0.02, 5);
        let (toks, mask) = batch(&bu, 7);
        let out = step_outputs(&bu, &tr, &toks, &mask);
        let loss0 = scalar_f32(&out[3 * n]).unwrap();
        assert!(loss0.is_finite() && loss0 > 0.0);

        // pick the largest-|g| coordinate of the first adapter
        let g: Vec<f32> = out[n].to_vec::<f32>().unwrap();
        let grad: Vec<f32> = g.iter().map(|x| x / (1.0 - 0.9)).collect();
        let (best, gbest) = grad
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
            .map(|(i, g)| (i, *g))
            .unwrap();
        assert!(gbest.abs() > 0.0, "zero gradient everywhere");

        let eps = 2e-2f32;
        let eval_at = |delta: f32| -> f32 {
            let mut tr2 = tr.clone();
            let mut data = tr2[0].to_vec::<f32>().unwrap();
            data[best] += delta;
            tr2[0] = lit_f32(&bu.trainable[0].shape, &data).unwrap();
            let out = step_outputs(&bu, &tr2, &toks, &mask);
            scalar_f32(&out[3 * n]).unwrap()
        };
        let fd = (eval_at(eps) - eval_at(-eps)) / (2.0 * eps);
        let rel = (fd - gbest).abs() / gbest.abs().max(1e-4);
        assert!(rel < 0.25, "FD {fd} vs analytic {gbest} (rel {rel})");
    }

    #[test]
    fn lora_b_gradient_nonzero_and_a_zero_at_init() {
        // At B = 0: dL/dA = 0 exactly, dL/dB != 0 — a sharp analytic
        // property of the LoRA backward.
        let bu = bundle("tiny_lora");
        let n = bu.n_trainable();
        let mut rng = Rng::new(3);
        let tr: Vec<Value> = bu
            .trainable
            .iter()
            .map(|s| {
                if s.name.ends_with(".lora_a") {
                    lit_f32(&s.shape, &rng.normal_vec(s.numel(), 0.01)).unwrap()
                } else {
                    lit_f32(&s.shape, &vec![0.0; s.numel()]).unwrap()
                }
            })
            .collect();
        let (toks, mask) = batch(&bu, 11);
        let out = step_outputs(&bu, &tr, &toks, &mask);
        let mut saw_b = false;
        for (i, spec) in bu.trainable.iter().enumerate() {
            let g: Vec<f32> = out[n + i].to_vec::<f32>().unwrap();
            let gmax = g.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
            if spec.name.ends_with(".lora_a") {
                assert!(gmax < 1e-12, "{}: dA should be 0 at B=0, got {gmax}", spec.name);
            } else {
                saw_b = saw_b || gmax > 1e-9;
            }
        }
        assert!(saw_b, "all lora_b gradients vanished");
    }

    #[test]
    fn rotate_fast_matches_naive_oracle() {
        let mut rng = Rng::new(9);
        let (m, b, nb) = (13, 8, 4);
        let d = b * nb;
        let packed = Tensor::randn(&[nb, peft::packed_dim(b)], 0.1, &mut rng);
        let blocks = build_cnp_blocks(&packed, b, 6).unwrap();
        let x = Tensor::randn(&[m, d], 1.0, &mut rng);
        let fast = block_rotate_fast(&x, &blocks).unwrap();
        let naive = peft::block_rotate(&x, &blocks).unwrap();
        assert!(fast.max_abs_diff(&naive) < 1e-5);
    }

    #[test]
    fn rotate_transposed_inverts_for_orthogonal_blocks() {
        // R^T is the inverse of an (approximately) orthogonal R.
        let mut rng = Rng::new(10);
        let (m, b, nb) = (6, 8, 2);
        let packed = Tensor::randn(&[nb, peft::packed_dim(b)], 0.02, &mut rng);
        let blocks = build_cnp_blocks(&packed, b, 8).unwrap();
        let x = Tensor::randn(&[m, b * nb], 1.0, &mut rng);
        let y = block_rotate_fast(&x, &blocks).unwrap();
        let back = block_rotate_transposed(&y, &blocks).unwrap();
        assert!(back.max_abs_diff(&x) < 1e-3, "{}", back.max_abs_diff(&x));
    }

    #[test]
    fn gelu_matches_reference_points() {
        // gelu(0) = 0, gelu(large) ~ x, gelu(-large) ~ 0
        let x = Tensor::from_vec(&[4], vec![0.0, 5.0, -5.0, 1.0]);
        let y = gelu_fwd(&x);
        assert!(y.data[0].abs() < 1e-7);
        assert!((y.data[1] - 5.0).abs() < 1e-3);
        assert!(y.data[2].abs() < 1e-3);
        assert!((y.data[3] - 0.8412).abs() < 1e-3); // known value
    }

    #[test]
    fn incremental_forward_matches_logits_last_exactly() {
        // The KV-cached row-at-a-time forward must reproduce the padded
        // whole-sequence forward's last-position logits exactly (same
        // kernels, same per-row accumulation order).
        for tag in ["tiny_oft_v2", "tiny_lora", "tiny_oft_merged"] {
            let bu = bundle(tag);
            let tr = random_values(&bu.trainable, 0.05, 21);
            let fixed: Vec<Value> = bu
                .frozen
                .iter()
                .map(|s| {
                    let t = crate::coordinator::state::init_param(s, 3, None).unwrap();
                    lit_f32(&s.shape, &t.data).unwrap()
                })
                .collect();
            let tr_refs: Vec<&Value> = tr.iter().collect();
            let fixed_refs: Vec<&Value> = fixed.iter().collect();

            let model = bu.decode_model(&tr_refs, &fixed_refs).unwrap();
            let mut cache = model.new_cache();
            let toks = [1i32, 7, 3, 9, 2];
            let mut inc = Vec::new();
            for &tk in &toks {
                inc = model.forward_incremental(&mut cache, tk).unwrap();
            }
            assert_eq!(cache.position(), toks.len());

            let t = bu.dims.seq_len;
            let mut padded: Vec<i32> = toks.to_vec();
            padded.resize(t, 0);
            let tokens = super::super::lit_i32(&[1, t], &padded).unwrap();
            let cur = super::super::lit_scalar_i32(toks.len() as i32);
            let mut inputs: Vec<&Value> = tr_refs.clone();
            inputs.extend(fixed_refs.iter().copied());
            inputs.push(&tokens);
            inputs.push(&cur);
            let out = bu.logits_last(&inputs).unwrap();
            assert_eq!(
                out[0].f32s().unwrap(),
                inc.as_slice(),
                "{tag}: incremental logits diverged from logits_last"
            );
        }
    }

    #[test]
    fn method_parsing() {
        assert_eq!(Method::parse("oft_v2").unwrap(), Method::OftV2);
        assert_eq!(Method::parse("qlora").unwrap(), Method::QLora);
        assert!(Method::parse("bogus").is_err());
        assert!(Method::Lora.is_lora() && Method::QLora.is_lora());
        assert!(Method::OftV2.is_oft_input_centric());
        assert_eq!(QuantKind::parse("nf4").unwrap(), QuantKind::Nf4);
    }
}
