//! The scenario subsystem: adapter-owned per-run configuration.
//!
//! Production OFT (the HF PEFT `OFTConfig`) exposes a scenario surface
//! beyond a single global `(r, b)` pair: COFT constraint projection
//! (`coft`/`eps`), multiplicative module dropout, `block_share`, `r`
//! vs `oft_block_size` selection, and `target_modules` /
//! `exclude_modules` regex targeting. This module owns the typed
//! [`ScenarioCfg`] carrying those knobs, parsed from three equivalent
//! sources that all land in the bundle tag:
//!
//! * **tag suffixes** — `tiny_oft_v2+coft+eps=1e-3+target=wq|wv`
//!   (the canonical carrier: anything resolving a tag through
//!   `Manifest::builtin` — trainer, decode, serve, merge, tests —
//!   sees the same scenario with zero extra plumbing);
//! * **CLI flags** — `--coft`, `--module-dropout 0.1`, ... (overlaid
//!   onto the tag, then re-canonicalized);
//! * **config files** — `[scenario]` keys via `config/toml.rs`.
//!
//! Each registered method declares which knobs it honors
//! ([`crate::adapters::Adapter::supported_knobs`]); unknown or
//! unsupported knobs are typed errors naming the valid options. The
//! numeric knobs ride inside [`ScenarioDims`] (a `Copy` struct
//! embedded in `ModelDims`) so they reach every adapter hook; the
//! targeting regexes resolve once at manifest synthesis into the
//! skipped-linear set.

pub mod regex;

use anyhow::{bail, ensure, Result};

use crate::tensor::Tensor;

/// Default COFT deviation bound (HF PEFT's `OFTConfig.eps` default).
pub const DEFAULT_EPS: f32 = 6e-5;

/// Default seed of the module-dropout decision stream. Dropout is a
/// pure function of `(seed, step, linear name)` — no stateful RNG — so
/// the decision is bitwise identical across workers, ranks, gradient
/// recomputes, and checkpoint resume.
pub const DEFAULT_DROPOUT_SEED: u64 = 0x0D40_B5EE_D0D4_0B1C;

/// Checkpoint key persisting the active [`ScenarioCfg`] (encoded by
/// [`ScenarioCfg::to_checkpoint_tensor`]). Written by full-state
/// checkpoints; resume validates it against the manifest's scenario.
pub const CKPT_KEY: &str = "__scenario";

/// One scenario knob — the unit of per-method support declaration.
/// `key()` is the spelling tag suffixes, CLI flags, and error messages
/// use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Knob {
    /// COFT: post-step constraint projection clamping the rotation
    /// parameters' deviation from identity to `eps`.
    Coft,
    /// The COFT deviation bound.
    Eps,
    /// Multiplicative module dropout: per step, each adapted linear
    /// independently falls back to the frozen base (identity adapter)
    /// with this probability.
    ModuleDropout,
    /// All rotation blocks of a linear share one parameter block.
    BlockShare,
    /// `r`: choose the number of rotation blocks per linear (block
    /// size becomes `din / r`). Mutually exclusive with `block`.
    R,
    /// `block` (`oft_block_size`): override the preset's block size.
    /// Mutually exclusive with `r`.
    BlockSize,
    /// Regex selecting which linears are adapted (others stay frozen).
    Target,
    /// Regex removing linears from the adapted set.
    Exclude,
}

impl Knob {
    /// All knobs, in canonical (suffix-serialization) order.
    pub const ALL: [Knob; 8] = [
        Knob::Coft,
        Knob::Eps,
        Knob::ModuleDropout,
        Knob::BlockShare,
        Knob::R,
        Knob::BlockSize,
        Knob::Target,
        Knob::Exclude,
    ];

    /// The tag-suffix / CLI spelling.
    pub fn key(self) -> &'static str {
        match self {
            Knob::Coft => "coft",
            Knob::Eps => "eps",
            Knob::ModuleDropout => "dropout",
            Knob::BlockShare => "block_share",
            Knob::R => "r",
            Knob::BlockSize => "block",
            Knob::Target => "target",
            Knob::Exclude => "exclude",
        }
    }
}

/// The valid scenario knob spellings, quoted by parse errors.
pub fn valid_keys() -> String {
    let mut keys: Vec<&str> = Knob::ALL.iter().map(|k| k.key()).collect();
    keys.push("dropout_seed");
    keys.join(", ")
}

/// The numeric scenario knobs, `Copy` so they embed in `ModelDims` and
/// flow through every adapter hook (parameter declaration, counting,
/// memory pricing) without threading a new argument. Targeting strings
/// stay on [`ScenarioCfg`] / the manifest.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ScenarioDims {
    pub coft: bool,
    pub eps: f32,
    pub module_dropout: f32,
    pub block_share: bool,
    /// `r` knob: number of rotation blocks per linear (0 = unset, use
    /// the preset's `block_b` block size instead).
    pub oft_r: usize,
    pub dropout_seed: u64,
}

impl Default for ScenarioDims {
    fn default() -> ScenarioDims {
        ScenarioDims {
            coft: false,
            eps: DEFAULT_EPS,
            module_dropout: 0.0,
            block_share: false,
            oft_r: 0,
            dropout_seed: DEFAULT_DROPOUT_SEED,
        }
    }
}

/// The full typed scenario configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioCfg {
    pub coft: bool,
    pub eps: f32,
    pub module_dropout: f32,
    pub block_share: bool,
    /// `r` knob (0 = unset): blocks per linear; block size = din / r.
    pub oft_r: usize,
    /// `block` knob (0 = unset): overrides the preset's `block_b`.
    pub block: usize,
    /// `target_modules` regex: only matching linears are adapted.
    pub target: Option<String>,
    /// `exclude_modules` regex: matching linears are never adapted.
    pub exclude: Option<String>,
    pub dropout_seed: u64,
}

impl Default for ScenarioCfg {
    fn default() -> ScenarioCfg {
        ScenarioCfg {
            coft: false,
            eps: DEFAULT_EPS,
            module_dropout: 0.0,
            block_share: false,
            oft_r: 0,
            block: 0,
            target: None,
            exclude: None,
            dropout_seed: DEFAULT_DROPOUT_SEED,
        }
    }
}

/// `'+'` inside a knob value (a regex quantifier, say) would split the
/// suffix; values escape it as `%2B` (and `%` as `%25`) so
/// [`ScenarioCfg::suffix`] / [`ScenarioCfg::parse_suffix`] round-trip
/// losslessly.
fn escape_value(v: &str) -> String {
    v.replace('%', "%25").replace('+', "%2B")
}

fn unescape_value(v: &str) -> String {
    v.replace("%2B", "+").replace("%25", "%")
}

impl ScenarioCfg {
    /// Parse a tag suffix (the part after the first `+`, itself
    /// `+`-separated): `coft+eps=1e-3+dropout=0.25+target=wq|wv`.
    /// Unknown knobs error with the valid-option list.
    pub fn parse_suffix(suffix: &str) -> Result<ScenarioCfg> {
        let mut sc = ScenarioCfg::default();
        for part in suffix.split('+') {
            if part.is_empty() {
                bail!("empty scenario knob in suffix '+{suffix}' (doubled '+'?)");
            }
            let (key, value) = match part.split_once('=') {
                Some((k, v)) => (k, Some(unescape_value(v))),
                None => (part, None),
            };
            let flag = || -> Result<()> {
                ensure!(
                    value.is_none(),
                    "scenario knob '{key}' is a flag and takes no value"
                );
                Ok(())
            };
            let val = |what: &str| -> Result<String> {
                value
                    .clone()
                    .ok_or_else(|| anyhow::anyhow!("scenario knob '{key}' needs a value ({what})"))
            };
            match key {
                "coft" => {
                    flag()?;
                    sc.coft = true;
                }
                "eps" => {
                    let v = val("a positive float")?;
                    let eps: f32 = v
                        .parse()
                        .map_err(|_| anyhow::anyhow!("scenario knob 'eps' expects a float, got '{v}'"))?;
                    ensure!(eps > 0.0 && eps.is_finite(), "scenario knob 'eps' must be > 0, got {eps}");
                    sc.eps = eps;
                }
                "dropout" => {
                    let v = val("a probability in [0, 1)")?;
                    let p: f32 = v.parse().map_err(|_| {
                        anyhow::anyhow!("scenario knob 'dropout' expects a float, got '{v}'")
                    })?;
                    ensure!(
                        (0.0..1.0).contains(&p),
                        "scenario knob 'dropout' must be in [0, 1), got {p}"
                    );
                    sc.module_dropout = p;
                }
                "dropout_seed" => {
                    let v = val("a u64 seed")?;
                    sc.dropout_seed = v.parse().map_err(|_| {
                        anyhow::anyhow!("scenario knob 'dropout_seed' expects an integer, got '{v}'")
                    })?;
                }
                "block_share" => {
                    flag()?;
                    sc.block_share = true;
                }
                "r" => {
                    let v = val("a positive block count")?;
                    let r: usize = v.parse().map_err(|_| {
                        anyhow::anyhow!("scenario knob 'r' expects an integer, got '{v}'")
                    })?;
                    ensure!(r > 0, "scenario knob 'r' must be > 0");
                    sc.oft_r = r;
                }
                "block" => {
                    let v = val("a positive block size")?;
                    let b: usize = v.parse().map_err(|_| {
                        anyhow::anyhow!("scenario knob 'block' expects an integer, got '{v}'")
                    })?;
                    ensure!(b > 0, "scenario knob 'block' must be > 0");
                    sc.block = b;
                }
                "target" => {
                    let v = val("a module regex")?;
                    regex::Regex::new(&v)?; // validate eagerly
                    sc.target = Some(v);
                }
                "exclude" => {
                    let v = val("a module regex")?;
                    regex::Regex::new(&v)?;
                    sc.exclude = Some(v);
                }
                other => bail!(
                    "unknown scenario knob '{other}'; valid knobs: {}",
                    valid_keys()
                ),
            }
        }
        sc.validate()?;
        Ok(sc)
    }

    /// Structural validation shared by every parse path.
    pub fn validate(&self) -> Result<()> {
        ensure!(
            !(self.oft_r > 0 && self.block > 0),
            "scenario knobs 'r' and 'block' are mutually exclusive \
             ('r' picks the number of rotation blocks, 'block' the block size)"
        );
        ensure!(
            self.eps > 0.0 && self.eps.is_finite(),
            "scenario knob 'eps' must be > 0, got {}",
            self.eps
        );
        ensure!(
            (0.0..1.0).contains(&self.module_dropout),
            "scenario knob 'dropout' must be in [0, 1), got {}",
            self.module_dropout
        );
        if let Some(t) = &self.target {
            regex::Regex::new(t)?;
        }
        if let Some(e) = &self.exclude {
            regex::Regex::new(e)?;
        }
        Ok(())
    }

    /// Is every knob at its default?
    pub fn is_default(&self) -> bool {
        *self == ScenarioCfg::default()
    }

    /// The knobs set away from their defaults (the set
    /// [`ScenarioCfg::validate_for`] checks against a method's
    /// declared support). A non-default `dropout_seed` counts as
    /// [`Knob::ModuleDropout`].
    pub fn knobs_set(&self) -> Vec<Knob> {
        let d = ScenarioCfg::default();
        let mut out = Vec::new();
        if self.coft != d.coft {
            out.push(Knob::Coft);
        }
        if self.eps != d.eps {
            out.push(Knob::Eps);
        }
        if self.module_dropout != d.module_dropout || self.dropout_seed != d.dropout_seed {
            out.push(Knob::ModuleDropout);
        }
        if self.block_share != d.block_share {
            out.push(Knob::BlockShare);
        }
        if self.oft_r != d.oft_r {
            out.push(Knob::R);
        }
        if self.block != d.block {
            out.push(Knob::BlockSize);
        }
        if self.target != d.target {
            out.push(Knob::Target);
        }
        if self.exclude != d.exclude {
            out.push(Knob::Exclude);
        }
        out
    }

    /// Reject knobs the method does not honor — the `configure` hook's
    /// default body. Errors name the method's supported knobs,
    /// matching the `Method`/`QuantKind` parse-error convention.
    pub fn validate_for(&self, method: &str, supported: &[Knob]) -> Result<()> {
        self.validate()?;
        for knob in self.knobs_set() {
            if !supported.contains(&knob) {
                let list = if supported.is_empty() {
                    "(none)".to_string()
                } else {
                    supported
                        .iter()
                        .map(|k| k.key())
                        .collect::<Vec<_>>()
                        .join(", ")
                };
                bail!(
                    "method '{method}' does not support scenario knob '{}'; \
                     supported knobs: {list}",
                    knob.key()
                );
            }
        }
        Ok(())
    }

    /// Canonical tag suffix (leading `+` included; empty when every
    /// knob is default). `parse_suffix(suffix()[1..])` round-trips.
    pub fn suffix(&self) -> String {
        let d = ScenarioCfg::default();
        let mut parts = Vec::new();
        if self.coft {
            parts.push("coft".to_string());
        }
        if self.eps != d.eps {
            parts.push(format!("eps={}", self.eps));
        }
        if self.module_dropout != d.module_dropout {
            parts.push(format!("dropout={}", self.module_dropout));
        }
        if self.dropout_seed != d.dropout_seed {
            parts.push(format!("dropout_seed={}", self.dropout_seed));
        }
        if self.block_share {
            parts.push("block_share".to_string());
        }
        if self.oft_r != 0 {
            parts.push(format!("r={}", self.oft_r));
        }
        if self.block != 0 {
            parts.push(format!("block={}", self.block));
        }
        if let Some(t) = &self.target {
            parts.push(format!("target={}", escape_value(t)));
        }
        if let Some(e) = &self.exclude {
            parts.push(format!("exclude={}", escape_value(e)));
        }
        if parts.is_empty() {
            String::new()
        } else {
            format!("+{}", parts.join("+"))
        }
    }

    /// Overlay `other`'s non-default knobs onto `self` (CLI flags and
    /// config-file keys win over an existing tag suffix).
    pub fn overlay(&mut self, other: &ScenarioCfg) {
        let d = ScenarioCfg::default();
        if other.coft != d.coft {
            self.coft = other.coft;
        }
        if other.eps != d.eps {
            self.eps = other.eps;
        }
        if other.module_dropout != d.module_dropout {
            self.module_dropout = other.module_dropout;
        }
        if other.dropout_seed != d.dropout_seed {
            self.dropout_seed = other.dropout_seed;
        }
        if other.block_share != d.block_share {
            self.block_share = other.block_share;
        }
        if other.oft_r != d.oft_r {
            self.oft_r = other.oft_r;
        }
        if other.block != d.block {
            self.block = other.block;
        }
        if other.target != d.target {
            self.target = other.target.clone();
        }
        if other.exclude != d.exclude {
            self.exclude = other.exclude.clone();
        }
    }

    /// The `Copy` numeric view embedded in `ModelDims`.
    pub fn dims(&self) -> ScenarioDims {
        ScenarioDims {
            coft: self.coft,
            eps: self.eps,
            module_dropout: self.module_dropout,
            block_share: self.block_share,
            oft_r: self.oft_r,
            dropout_seed: self.dropout_seed,
        }
    }

    /// Resolve the targeting regexes against the bundle's adapted
    /// linear names: returns the *skipped* names (sorted), i.e. those
    /// not matching `target` (when set) or matching `exclude`. A
    /// `target` pattern matching nothing is a typed error naming the
    /// available linears.
    pub fn resolve_skipped(&self, names: &[String]) -> Result<Vec<String>> {
        let target = self.target.as_deref().map(regex::Regex::new).transpose()?;
        let exclude = self.exclude.as_deref().map(regex::Regex::new).transpose()?;
        let mut skipped = Vec::new();
        let mut targeted_any = false;
        for name in names {
            let hit = target.as_ref().is_none_or(|t| t.is_match(name))
                && !exclude.as_ref().is_some_and(|e| e.is_match(name));
            if hit {
                targeted_any = true;
            } else {
                skipped.push(name.clone());
            }
        }
        if let Some(t) = &self.target {
            ensure!(
                targeted_any,
                "target_modules regex '{}' matches none of the adapted linears \
                 (available: {})",
                t.pattern(),
                names.join(", ")
            );
        }
        skipped.sort();
        Ok(skipped)
    }

    // -- checkpoint persistence ----------------------------------------

    /// Encode into an f32 tensor for the checkpoint payload (16-bit
    /// halves for the integer fields, the shard-meta idiom; regex
    /// strings as one length + byte-per-element runs). Version-tagged.
    pub fn to_checkpoint_tensor(&self) -> Tensor {
        let mut data: Vec<f32> = vec![
            1.0, // encoding version
            if self.coft { 1.0 } else { 0.0 },
            self.eps,
            self.module_dropout,
            if self.block_share { 1.0 } else { 0.0 },
            self.oft_r as f32,
            self.block as f32,
            (self.dropout_seed & 0xffff) as f32,
            ((self.dropout_seed >> 16) & 0xffff) as f32,
            ((self.dropout_seed >> 32) & 0xffff) as f32,
            ((self.dropout_seed >> 48) & 0xffff) as f32,
        ];
        for s in [&self.target, &self.exclude] {
            match s {
                Some(v) => {
                    let bytes = v.as_bytes();
                    data.push(bytes.len() as f32);
                    data.extend(bytes.iter().map(|&b| b as f32));
                }
                None => data.push(-1.0),
            }
        }
        let n = data.len();
        Tensor::from_vec(&[n], data)
    }

    /// Decode [`ScenarioCfg::to_checkpoint_tensor`].
    pub fn from_checkpoint_tensor(t: &Tensor) -> Result<ScenarioCfg> {
        let d = &t.data;
        ensure!(d.len() >= 13, "'{CKPT_KEY}' entry too short ({} values)", d.len());
        ensure!(
            d[0] == 1.0,
            "'{CKPT_KEY}' encoding v{} unsupported (max 1)",
            d[0]
        );
        let u16x = |x: f32| (x as u64) & 0xffff;
        let seed = u16x(d[7]) | (u16x(d[8]) << 16) | (u16x(d[9]) << 32) | (u16x(d[10]) << 48);
        let mut pos = 11usize;
        let mut read_str = || -> Result<Option<String>> {
            ensure!(pos < d.len(), "'{CKPT_KEY}' entry truncated");
            let len = d[pos];
            pos += 1;
            if len < 0.0 {
                return Ok(None);
            }
            let n = len as usize;
            ensure!(pos + n <= d.len(), "'{CKPT_KEY}' string overruns the entry");
            let bytes: Vec<u8> = d[pos..pos + n].iter().map(|&x| x as u8).collect();
            pos += n;
            Ok(Some(String::from_utf8(bytes).map_err(|_| {
                anyhow::anyhow!("'{CKPT_KEY}' holds a non-UTF-8 regex")
            })?))
        };
        let target = read_str()?;
        let exclude = read_str()?;
        let sc = ScenarioCfg {
            coft: d[1] != 0.0,
            eps: d[2],
            module_dropout: d[3],
            block_share: d[4] != 0.0,
            oft_r: d[5] as usize,
            block: d[6] as usize,
            target,
            exclude,
            dropout_seed: seed,
        };
        sc.validate()?;
        Ok(sc)
    }
}

/// Split a bundle tag into its base (`<preset>_<method>[_<quant>]`)
/// and parsed scenario suffix.
pub fn split_tag(tag: &str) -> Result<(String, ScenarioCfg)> {
    match tag.split_once('+') {
        Some((base, suffix)) => Ok((base.to_string(), ScenarioCfg::parse_suffix(suffix)?)),
        None => Ok((tag.to_string(), ScenarioCfg::default())),
    }
}

/// Overlay `overrides` (CLI flags / config keys) onto `tag`'s existing
/// suffix and return the canonical tag. The canonical tag is the one
/// carrier of the scenario: every consumer (train, decode, serve,
/// merge) resolves it through `Manifest::builtin`.
pub fn apply_to_tag(tag: &str, overrides: &ScenarioCfg) -> Result<String> {
    let (base, mut sc) = split_tag(tag)?;
    sc.overlay(overrides);
    sc.validate()?;
    Ok(format!("{base}{}", sc.suffix()))
}

// ---------------------------------------------------------------------------
// Module dropout: a pure per-(linear, step) decision
// ---------------------------------------------------------------------------

/// FNV-1a over a linear name (the same per-name stream-splitting hash
/// parameter init uses).
fn fnv1a(name: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// Whether `linear` is dropped (falls back to the frozen base path)
/// at optimizer step `step`. A pure function of
/// `(dropout_seed, step, name)` — no RNG state to thread — so the
/// decision is bitwise identical across `--workers`, `--ranks`,
/// gradient-checkpoint recomputes, and checkpoint resume (`__step`
/// restores the counter, the checkpoint restores the seed).
pub fn dropped(linear: &str, step: u64, sd: &ScenarioDims) -> bool {
    if sd.module_dropout <= 0.0 {
        return false;
    }
    // splitmix64 finalizer over the mixed (seed, step, name) word.
    let mut h = sd
        .dropout_seed
        .wrapping_add(step.wrapping_mul(0x9E37_79B9_97F4_A7C1))
        ^ fnv1a(linear);
    h ^= h >> 30;
    h = h.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    h ^= h >> 27;
    h = h.wrapping_mul(0x94D0_49BB_1331_11EB);
    h ^= h >> 31;
    let u = (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
    u < sd.module_dropout as f64
}

// ---------------------------------------------------------------------------
// COFT: post-step constraint projection
// ---------------------------------------------------------------------------

/// Project one trainable tensor onto the COFT constraint set: the
/// adapter parameters are zero at identity, so the Frobenius norm of
/// the tensor *is* its deviation from the identity rotation; clamp it
/// to `eps` by uniform scaling. Sequential accumulation order — the
/// projection runs on the full post-all-gather parameters on every
/// rank, so it is bitwise identical from 1 thread to N workers/ranks.
/// Returns whether the tensor was clamped.
pub fn coft_project(data: &mut [f32], eps: f32) -> bool {
    let norm = frobenius(data);
    if norm <= eps || norm == 0.0 {
        return false;
    }
    let scale = eps / norm;
    for x in data.iter_mut() {
        *x *= scale;
    }
    true
}

/// Frobenius norm, fixed sequential order (f64 accumulator).
pub fn frobenius(data: &[f32]) -> f32 {
    let mut acc = 0.0f64;
    for &x in data {
        acc += (x as f64) * (x as f64);
    }
    (acc.sqrt()) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_roundtrips_empty_suffix() {
        let sc = ScenarioCfg::default();
        assert!(sc.is_default());
        assert_eq!(sc.suffix(), "");
        assert!(sc.knobs_set().is_empty());
    }

    #[test]
    fn suffix_roundtrip() {
        let sc = ScenarioCfg {
            coft: true,
            eps: 1e-3,
            module_dropout: 0.25,
            block_share: true,
            oft_r: 4,
            block: 0,
            target: Some("wq|wv".into()),
            exclude: Some("mlp".into()),
            dropout_seed: 99,
        };
        let suffix = sc.suffix();
        let back = ScenarioCfg::parse_suffix(&suffix[1..]).unwrap();
        assert_eq!(back, sc);
    }

    #[test]
    fn plus_in_regex_values_escapes() {
        let sc = ScenarioCfg {
            target: Some("w[qv]+x".into()),
            ..Default::default()
        };
        let suffix = sc.suffix();
        assert!(suffix.contains("%2B"), "{suffix}");
        let back = ScenarioCfg::parse_suffix(&suffix[1..]).unwrap();
        assert_eq!(back.target.as_deref(), Some("w[qv]+x"));
    }

    #[test]
    fn unknown_knob_lists_valid_options() {
        let err = match ScenarioCfg::parse_suffix("warp=9") {
            Err(e) => format!("{e:#}"),
            Ok(_) => panic!("'warp' should not parse"),
        };
        for key in ["coft", "eps", "dropout", "block_share", "r", "block", "target", "exclude"] {
            assert!(err.contains(key), "error should list '{key}': {err}");
        }
    }

    #[test]
    fn malformed_values_are_typed_errors() {
        assert!(ScenarioCfg::parse_suffix("eps=zero").is_err());
        assert!(ScenarioCfg::parse_suffix("eps=-1").is_err());
        assert!(ScenarioCfg::parse_suffix("dropout=1.5").is_err());
        assert!(ScenarioCfg::parse_suffix("dropout").is_err()); // needs a value
        assert!(ScenarioCfg::parse_suffix("coft=yes").is_err()); // flag takes none
        assert!(ScenarioCfg::parse_suffix("r=0").is_err());
        assert!(ScenarioCfg::parse_suffix("r=2+block=8").is_err()); // mutually exclusive
        assert!(ScenarioCfg::parse_suffix("target=(wq").is_err()); // bad regex
        assert!(ScenarioCfg::parse_suffix("coft++eps=1e-3").is_err()); // doubled '+'
    }

    #[test]
    fn validate_for_rejects_unsupported_knobs() {
        let sc = ScenarioCfg {
            coft: true,
            ..Default::default()
        };
        let err = match sc.validate_for("lora", &[Knob::ModuleDropout, Knob::Target]) {
            Err(e) => format!("{e:#}"),
            Ok(()) => panic!("coft should be unsupported"),
        };
        assert!(err.contains("'coft'"), "{err}");
        assert!(err.contains("dropout"), "{err}");
        assert!(err.contains("target"), "{err}");
        sc.validate_for("oft_v2", &Knob::ALL).unwrap();
        // no knobs set passes any support list
        ScenarioCfg::default().validate_for("none", &[]).unwrap();
    }

    #[test]
    fn overlay_non_defaults_win() {
        let mut base = ScenarioCfg::parse_suffix("coft+eps=1e-3").unwrap();
        let over = ScenarioCfg {
            module_dropout: 0.1,
            eps: 2e-3,
            ..Default::default()
        };
        base.overlay(&over);
        assert!(base.coft);
        assert_eq!(base.eps, 2e-3);
        assert_eq!(base.module_dropout, 0.1);
    }

    #[test]
    fn apply_to_tag_canonicalizes() {
        let tag = apply_to_tag("tiny_oft_v2", &ScenarioCfg::default()).unwrap();
        assert_eq!(tag, "tiny_oft_v2");
        let over = ScenarioCfg {
            coft: true,
            ..Default::default()
        };
        let tag = apply_to_tag("tiny_oft_v2+eps=0.001", &over).unwrap();
        assert_eq!(tag, "tiny_oft_v2+coft+eps=0.001");
        // idempotent: re-applying defaults keeps the canonical form
        assert_eq!(apply_to_tag(&tag, &ScenarioCfg::default()).unwrap(), tag);
    }

    #[test]
    fn targeting_resolution() {
        let names: Vec<String> = vec![
            "layers.0.attn.wq".into(),
            "layers.0.attn.wv".into(),
            "layers.0.mlp.up".into(),
        ];
        let all = ScenarioCfg::default().resolve_skipped(&names).unwrap();
        assert!(all.is_empty());
        let sc = ScenarioCfg {
            target: Some("wq|wv".into()),
            ..Default::default()
        };
        assert_eq!(sc.resolve_skipped(&names).unwrap(), vec!["layers.0.mlp.up".to_string()]);
        let sc = ScenarioCfg {
            exclude: Some("mlp".into()),
            ..Default::default()
        };
        assert_eq!(sc.resolve_skipped(&names).unwrap(), vec!["layers.0.mlp.up".to_string()]);
        // target matching nothing is a typed error naming the linears
        let sc = ScenarioCfg {
            target: Some("zzz".into()),
            ..Default::default()
        };
        let err = format!("{:#}", sc.resolve_skipped(&names).unwrap_err());
        assert!(err.contains("matches none"), "{err}");
        assert!(err.contains("layers.0.attn.wq"), "{err}");
    }

    #[test]
    fn dropout_is_deterministic_and_distributed() {
        let sd = ScenarioDims {
            module_dropout: 0.5,
            ..Default::default()
        };
        // pure function: same inputs, same answer
        for step in 0..20u64 {
            assert_eq!(
                dropped("layers.0.attn.wq", step, &sd),
                dropped("layers.0.attn.wq", step, &sd)
            );
        }
        // roughly the right rate over many (step, name) pairs
        let mut hits = 0usize;
        let n = 4000usize;
        for step in 0..n as u64 {
            if dropped("layers.1.mlp.up", step, &sd) {
                hits += 1;
            }
        }
        let rate = hits as f64 / n as f64;
        assert!((rate - 0.5).abs() < 0.05, "dropout rate {rate}");
        // p = 0 never drops; different seeds decide differently somewhere
        let off = ScenarioDims::default();
        assert!(!dropped("layers.0.attn.wq", 3, &off));
        let sd2 = ScenarioDims {
            dropout_seed: 1234,
            ..sd
        };
        assert!((0..200u64).any(|s| dropped("x", s, &sd) != dropped("x", s, &sd2)));
    }

    #[test]
    fn coft_projection_clamps_to_eps() {
        let mut data = vec![0.3f32, -0.4, 0.0, 1.2];
        let norm0 = frobenius(&data);
        assert!(norm0 > 1e-2);
        assert!(coft_project(&mut data, 1e-2));
        let norm1 = frobenius(&data);
        assert!((norm1 - 1e-2).abs() < 1e-6, "{norm1}");
        // direction preserved
        assert!(data[0] > 0.0 && data[1] < 0.0 && data[2] == 0.0);
        // already-feasible tensors are untouched
        let mut small = vec![1e-6f32; 4];
        let before = small.clone();
        assert!(!coft_project(&mut small, 1e-2));
        assert_eq!(small, before);
    }

    #[test]
    fn checkpoint_tensor_roundtrip() {
        for sc in [
            ScenarioCfg::default(),
            ScenarioCfg {
                coft: true,
                eps: 3e-4,
                module_dropout: 0.15,
                block_share: true,
                oft_r: 8,
                block: 0,
                target: Some("w[qv]$".into()),
                exclude: None,
                dropout_seed: 0xDEAD_BEEF_1234_5678,
            },
        ] {
            let t = sc.to_checkpoint_tensor();
            let back = ScenarioCfg::from_checkpoint_tensor(&t).unwrap();
            assert_eq!(back, sc);
        }
        // future encoding version is a typed error
        let mut t = ScenarioCfg::default().to_checkpoint_tensor();
        t.data[0] = 2.0;
        assert!(ScenarioCfg::from_checkpoint_tensor(&t).is_err());
    }

    #[test]
    fn split_tag_handles_suffixes() {
        let (base, sc) = split_tag("tiny_oft_v2").unwrap();
        assert_eq!(base, "tiny_oft_v2");
        assert!(sc.is_default());
        let (base, sc) = split_tag("tiny_oft_v2+coft+r=4").unwrap();
        assert_eq!(base, "tiny_oft_v2");
        assert!(sc.coft);
        assert_eq!(sc.oft_r, 4);
        assert!(split_tag("tiny_oft_v2+warp").is_err());
    }
}
