//! A small self-contained regex engine for module targeting
//! (`target_modules` / `exclude_modules`), so patterns like
//! `q_proj|v_proj` or `layers\.[01]\.attn\..*` resolve against linear
//! names with zero external dependencies.
//!
//! Supported constructs: literals, `.` (any char), `*` / `+` / `?`
//! postfix repetition, `|` alternation, `(...)` groups, `[abc]` /
//! `[a-z]` / `[^abc]` character classes, `^` / `$` anchors, and `\x`
//! escapes. Matching is unanchored substring search (PEFT semantics:
//! a pattern targets every module whose name *contains* a match)
//! unless the pattern anchors itself. Malformed patterns are typed
//! errors naming the supported constructs, matching the
//! `Method`/`QuantKind` parse-error convention.

use anyhow::{bail, Result};

/// The constructs this engine understands — quoted verbatim by every
/// parse error so a bad pattern teaches the valid surface.
pub const SUPPORTED: &str =
    "literals, '.', '*', '+', '?', '|', '(...)', '[abc]'/'[a-z]'/'[^abc]', '^', '$', '\\' escapes";

/// A compiled pattern.
#[derive(Debug, Clone)]
pub struct Regex {
    pattern: String,
    ast: Alt,
}

#[derive(Debug, Clone)]
enum ClassItem {
    Ch(char),
    Range(char, char),
}

#[derive(Debug, Clone)]
enum Node {
    Lit(char),
    Any,
    Class { neg: bool, items: Vec<ClassItem> },
    Group(Alt),
    Start,
    End,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Rep {
    One,
    Star,
    Plus,
    Quest,
}

#[derive(Debug, Clone)]
struct Piece {
    node: Node,
    rep: Rep,
}

#[derive(Debug, Clone)]
struct Seq {
    pieces: Vec<Piece>,
}

#[derive(Debug, Clone)]
struct Alt {
    seqs: Vec<Seq>,
}

struct Parser {
    chars: Vec<char>,
    pos: usize,
    pattern: String,
}

impl Parser {
    fn err(&self, what: &str) -> anyhow::Error {
        anyhow::anyhow!(
            "bad regex '{}': {what} (at position {}); supported constructs: {SUPPORTED}",
            self.pattern,
            self.pos
        )
    }

    fn peek(&self) -> Option<char> {
        self.chars.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn parse_alt(&mut self) -> Result<Alt> {
        let mut seqs = vec![self.parse_seq()?];
        while self.peek() == Some('|') {
            self.bump();
            seqs.push(self.parse_seq()?);
        }
        Ok(Alt { seqs })
    }

    fn parse_seq(&mut self) -> Result<Seq> {
        let mut pieces = Vec::new();
        while let Some(c) = self.peek() {
            if c == '|' || c == ')' {
                break;
            }
            pieces.push(self.parse_piece()?);
        }
        Ok(Seq { pieces })
    }

    fn parse_piece(&mut self) -> Result<Piece> {
        let node = self.parse_atom()?;
        let rep = match self.peek() {
            Some('*') => {
                self.bump();
                Rep::Star
            }
            Some('+') => {
                self.bump();
                Rep::Plus
            }
            Some('?') => {
                self.bump();
                Rep::Quest
            }
            _ => Rep::One,
        };
        if rep != Rep::One && matches!(node, Node::Start | Node::End) {
            return Err(self.err("a '^'/'$' anchor cannot be repeated"));
        }
        Ok(Piece { node, rep })
    }

    fn parse_atom(&mut self) -> Result<Node> {
        let c = self.bump().ok_or_else(|| self.err("pattern ended unexpectedly"))?;
        Ok(match c {
            '(' => {
                let inner = self.parse_alt()?;
                if self.bump() != Some(')') {
                    return Err(self.err("unclosed '(' group"));
                }
                Node::Group(inner)
            }
            ')' => return Err(self.err("unmatched ')'")),
            '[' => self.parse_class()?,
            ']' => Node::Lit(']'),
            '.' => Node::Any,
            '^' => Node::Start,
            '$' => Node::End,
            '*' | '+' | '?' => {
                self.pos -= 1;
                return Err(self.err(&format!("'{c}' repetition needs something to repeat")));
            }
            '\\' => {
                let e = self
                    .bump()
                    .ok_or_else(|| self.err("trailing '\\' escapes nothing"))?;
                Node::Lit(e)
            }
            other => Node::Lit(other),
        })
    }

    fn parse_class(&mut self) -> Result<Node> {
        let neg = if self.peek() == Some('^') {
            self.bump();
            true
        } else {
            false
        };
        let mut items = Vec::new();
        loop {
            let c = match self.bump() {
                None => return Err(self.err("unclosed '[' character class")),
                Some(']') if !items.is_empty() || neg => break,
                Some(']') => break, // '[]' => empty class (matches nothing)
                Some('\\') => self
                    .bump()
                    .ok_or_else(|| self.err("trailing '\\' escapes nothing"))?,
                Some(c) => c,
            };
            if self.peek() == Some('-')
                && self.chars.get(self.pos + 1).is_some_and(|&n| n != ']')
            {
                self.bump(); // '-'
                let hi = match self.bump() {
                    Some('\\') => self
                        .bump()
                        .ok_or_else(|| self.err("trailing '\\' escapes nothing"))?,
                    Some(h) => h,
                    None => return Err(self.err("unclosed '[' character class")),
                };
                if hi < c {
                    return Err(self.err(&format!("class range '{c}-{hi}' is reversed")));
                }
                items.push(ClassItem::Range(c, hi));
            } else {
                items.push(ClassItem::Ch(c));
            }
        }
        Ok(Node::Class { neg, items })
    }
}

impl Regex {
    /// Compile a pattern; malformed input errors name the supported
    /// constructs.
    pub fn new(pattern: &str) -> Result<Regex> {
        let mut p = Parser {
            chars: pattern.chars().collect(),
            pos: 0,
            pattern: pattern.to_string(),
        };
        let ast = p.parse_alt()?;
        if p.pos < p.chars.len() {
            // Only a stray ')' can stop parse_alt early at top level.
            bail!(
                "bad regex '{pattern}': unmatched ')' (at position {}); supported constructs: {SUPPORTED}",
                p.pos
            );
        }
        Ok(Regex {
            pattern: pattern.to_string(),
            ast,
        })
    }

    /// The source pattern.
    pub fn pattern(&self) -> &str {
        &self.pattern
    }

    /// Unanchored substring match (use `^`/`$` in the pattern to
    /// anchor): does any substring of `text` match?
    pub fn is_match(&self, text: &str) -> bool {
        let chars: Vec<char> = text.chars().collect();
        for start in 0..=chars.len() {
            if !ends_alt(&self.ast, &chars, start).is_empty() {
                return true;
            }
        }
        false
    }
}

/// All end positions reachable by matching `alt` at `pos` (deduped,
/// ascending). Backtracking over explicit position sets: fine for the
/// short module names this engine targets.
fn ends_alt(alt: &Alt, t: &[char], pos: usize) -> Vec<usize> {
    let mut out = Vec::new();
    for seq in &alt.seqs {
        merge(&mut out, ends_seq(seq, t, pos));
    }
    out
}

fn ends_seq(seq: &Seq, t: &[char], pos: usize) -> Vec<usize> {
    let mut set = vec![pos];
    for piece in &seq.pieces {
        let mut next = Vec::new();
        for &p in &set {
            merge(&mut next, ends_piece(piece, t, p));
        }
        set = next;
        if set.is_empty() {
            break;
        }
    }
    set
}

fn ends_piece(piece: &Piece, t: &[char], pos: usize) -> Vec<usize> {
    match piece.rep {
        Rep::One => ends_node(&piece.node, t, pos),
        Rep::Quest => {
            let mut out = vec![pos];
            merge(&mut out, ends_node(&piece.node, t, pos));
            out
        }
        Rep::Star | Rep::Plus => {
            let mut out = if piece.rep == Rep::Star {
                vec![pos]
            } else {
                Vec::new()
            };
            let mut frontier = vec![pos];
            loop {
                let mut fresh = Vec::new();
                for &p in &frontier {
                    for e in ends_node(&piece.node, t, p) {
                        if !out.contains(&e) && !fresh.contains(&e) {
                            fresh.push(e);
                        }
                    }
                }
                if fresh.is_empty() {
                    break;
                }
                merge(&mut out, fresh.clone());
                frontier = fresh;
            }
            out
        }
    }
}

fn ends_node(node: &Node, t: &[char], pos: usize) -> Vec<usize> {
    match node {
        Node::Lit(c) => match t.get(pos) {
            Some(x) if x == c => vec![pos + 1],
            _ => Vec::new(),
        },
        Node::Any => {
            if pos < t.len() {
                vec![pos + 1]
            } else {
                Vec::new()
            }
        }
        Node::Class { neg, items } => match t.get(pos) {
            Some(&x) => {
                let inside = items.iter().any(|it| match *it {
                    ClassItem::Ch(c) => c == x,
                    ClassItem::Range(lo, hi) => (lo..=hi).contains(&x),
                });
                if inside != *neg {
                    vec![pos + 1]
                } else {
                    Vec::new()
                }
            }
            None => Vec::new(),
        },
        Node::Group(a) => ends_alt(a, t, pos),
        Node::Start => {
            if pos == 0 {
                vec![pos]
            } else {
                Vec::new()
            }
        }
        Node::End => {
            if pos == t.len() {
                vec![pos]
            } else {
                Vec::new()
            }
        }
    }
}

fn merge(out: &mut Vec<usize>, add: Vec<usize>) {
    for e in add {
        if !out.contains(&e) {
            out.push(e);
        }
    }
    out.sort_unstable();
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(pat: &str, text: &str) -> bool {
        Regex::new(pat).unwrap().is_match(text)
    }

    #[test]
    fn literal_substring() {
        assert!(m("wq", "layers.0.attn.wq"));
        assert!(m("attn", "layers.0.attn.wq"));
        assert!(!m("wz", "layers.0.attn.wq"));
        assert!(m("", "anything")); // empty pattern matches everywhere
    }

    #[test]
    fn alternation() {
        assert!(m("wq|wv", "layers.0.attn.wq"));
        assert!(m("wq|wv", "layers.1.attn.wv"));
        assert!(!m("wq|wv", "layers.1.attn.wk"));
        assert!(m("q_proj|v_proj", "model.layers.3.self_attn.q_proj"));
    }

    #[test]
    fn dot_and_escapes() {
        assert!(m("attn.wq", "layers.0.attnXwq")); // '.' is any
        assert!(m("attn\\.wq", "layers.0.attn.wq"));
        assert!(!m("attn\\.wq", "layers.0.attnXwq"));
        assert!(m("\\|", "a|b"));
    }

    #[test]
    fn repetition() {
        assert!(m("ab*c", "ac"));
        assert!(m("ab*c", "abbbc"));
        assert!(m("ab+c", "abc"));
        assert!(!m("ab+c", "ac"));
        assert!(m("ab?c", "ac"));
        assert!(m("ab?c", "abc"));
        assert!(m("layers\\..*\\.wq", "layers.12.attn.wq"));
    }

    #[test]
    fn classes_and_ranges() {
        assert!(m("w[qv]", "attn.wq"));
        assert!(m("w[qv]", "attn.wv"));
        assert!(!m("w[qv]", "attn.wk"));
        assert!(m("layers\\.[0-3]\\.", "layers.2.attn.wq"));
        assert!(!m("layers\\.[0-3]\\.", "layers.5.attn.wq"));
        assert!(m("w[^qv]", "attn.wk"));
        assert!(!m("w[^qv]$", "attn.wq"));
    }

    #[test]
    fn anchors() {
        assert!(m("^layers", "layers.0.attn.wq"));
        assert!(!m("^attn", "layers.0.attn.wq"));
        assert!(m("wq$", "layers.0.attn.wq"));
        assert!(!m("attn$", "layers.0.attn.wq"));
        assert!(m("^layers\\.0\\.attn\\.wq$", "layers.0.attn.wq"));
    }

    #[test]
    fn groups() {
        assert!(m("(wq|wv)$", "layers.0.attn.wq"));
        assert!(!m("(wq|wv)$", "layers.0.attn.wk"));
        assert!(m("(ab)+c", "ababc"));
        assert!(!m("(ab)+c", "c"));
    }

    #[test]
    fn malformed_patterns_error_naming_constructs() {
        for bad in ["(wq", "wq)", "*wq", "+wq", "?x", "[qv", "a\\", "[z-a]"] {
            let err = match Regex::new(bad) {
                Err(e) => format!("{e:#}"),
                Ok(_) => panic!("'{bad}' should not compile"),
            };
            assert!(
                err.contains("supported constructs"),
                "'{bad}' error should name the supported constructs: {err}"
            );
            assert!(err.contains(bad), "'{bad}' error should quote the pattern: {err}");
        }
    }

    #[test]
    fn empty_class_matches_nothing() {
        assert!(!m("w[]", "wq"));
    }
}
