//! Resource allocation for the serving loop: the shared KV block
//! budget (admission-time reservations over a [`KvBlockPool`]) and LRU
//! paging of adapter decoders under a residency cap.
//!
//! Reservations are worst-case: a request is admitted only when the
//! pool can cover `ceil(min(prompt + max_new, seq_len) / block_tokens)`
//! blocks on top of every other active sequence's reservation, so the
//! lazy per-block allocation inside a paged session can never fail
//! mid-decode. Most sequences finish early (EOS) and return their
//! blocks without ever drawing the full reservation.

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::manifest::{Manifest, ModelDims};
use crate::coordinator::state::BaseModel;
use crate::runtime::{Buffer, Decoder, Engine, KvBlockPool, KvPoolStats, SharedKvPool, Value};

use super::{ServeConfig, Server};

/// One attached adapter: the retained state needed to (re)build its
/// decoder, plus the LRU bookkeeping that pages the decoder in and out.
/// The manifest and trainables stay resident always — they are the
/// small per-tenant state; the decoder holds the merged/resolved
/// weights and is the thing worth evicting.
pub(crate) struct Adapter {
    pub(crate) manifest: Manifest,
    pub(crate) trainables: Vec<Value>,
    /// For merged-artifact residents: a private base holding the merged
    /// weights (uploaded once at attach). `None` = a live adapter on
    /// the server's shared base.
    pub(crate) base: Option<Arc<BaseModel>>,
    /// `None` while paged out; rebuilt on the next request.
    pub(crate) decoder: Option<Decoder>,
    /// LRU clock stamp of the last touch.
    pub(crate) last_used: u64,
    /// Active sequences pinning this adapter (never evict while > 0).
    pub(crate) active_seqs: usize,
    /// Times the decoder was rebuilt after an eviction.
    pub(crate) page_ins: u64,
}

impl Adapter {
    pub(crate) fn new(manifest: Manifest, trainables: Vec<Value>, decoder: Decoder) -> Adapter {
        Adapter {
            manifest,
            trainables,
            base: None,
            decoder: Some(decoder),
            last_used: 0,
            active_seqs: 0,
            page_ins: 0,
        }
    }

    /// A merged-artifact resident: zero trainables, decoding against a
    /// private base instead of the server's shared one.
    pub(crate) fn merged(manifest: Manifest, base: Arc<BaseModel>, decoder: Decoder) -> Adapter {
        Adapter {
            manifest,
            trainables: Vec::new(),
            base: Some(base),
            decoder: Some(decoder),
            last_used: 0,
            active_seqs: 0,
            page_ins: 0,
        }
    }

    /// Whether this resident is a merged artifact (private base).
    pub(crate) fn is_merged(&self) -> bool {
        self.base.is_some()
    }
}

/// LRU clock + residency cap for adapter decoders.
pub(crate) struct AdapterPager {
    max_resident: Option<usize>,
    clock: u64,
}

impl AdapterPager {
    pub(crate) fn new(max_resident: Option<usize>) -> AdapterPager {
        AdapterPager { max_resident, clock: 0 }
    }

    pub(crate) fn max_resident(&self) -> Option<usize> {
        self.max_resident
    }

    pub(crate) fn touch(&mut self, a: &mut Adapter) {
        self.clock += 1;
        a.last_used = self.clock;
    }
}

/// Resolve an adapter's decoder against the shared base. The base's
/// buffer/pack caches make this re-runnable: a rebuild after eviction
/// uploads nothing (`Engine::upload_count()` stays flat).
pub(crate) fn build_decoder(
    engine: &Engine,
    base: &BaseModel,
    manifest: &Manifest,
    trainables: &[Value],
) -> Result<Decoder> {
    let fixed = base.fixed_for(engine, manifest)?;
    let tr: Vec<&Value> = trainables.iter().collect();
    let fixed_refs: Vec<&Buffer> = fixed.iter().map(|a| a.as_ref()).collect();
    engine.load_decoder(manifest, &tr, &fixed_refs)
}

/// The server's view of the shared KV pool: capacity, outstanding
/// admission reservations, and the pool handle sessions decode against.
pub(crate) struct KvBudget {
    pool: Option<SharedKvPool>,
    capacity: usize,
    block_tokens: usize,
    reserved: usize,
    /// Whether capacity came from the default sizing rule (`max_batch`
    /// full-length sequences) rather than an explicit `max_kv_blocks`.
    /// Default-sized pools grow when a later adapter has a longer
    /// seq_len than the one the pool was first sized for.
    default_sized: bool,
    /// Set when the backend reported no paged path — requests fall back
    /// to contiguous sessions and the budget stops gating admission.
    demoted: bool,
}

impl KvBudget {
    pub(crate) fn new() -> KvBudget {
        KvBudget {
            pool: None,
            capacity: 0,
            block_tokens: 1,
            reserved: 0,
            default_sized: false,
            demoted: false,
        }
    }

    pub(crate) fn is_paged(&self) -> bool {
        self.pool.is_some()
    }

    pub(crate) fn pool(&self) -> Option<&SharedKvPool> {
        self.pool.as_ref()
    }

    pub(crate) fn capacity(&self) -> usize {
        self.capacity
    }

    /// Build the shared pool on first adapter attach (all adapters
    /// share one base, hence one KV row shape). A backend without a
    /// paged path demotes the server to contiguous sessions.
    ///
    /// Called again for every later attach: a default-sized pool grows
    /// to cover the largest seq_len seen, so an adapter attached after
    /// pool creation never ends up with a worst-case block need the
    /// capacity can't satisfy. An explicit `max_kv_blocks` stays a hard
    /// cap — requests that can never fit it are rejected at submit
    /// (see [`super::RejectReason::KvExceedsPool`]).
    pub(crate) fn ensure_pool(
        &mut self,
        decoder: &Decoder,
        dims: &ModelDims,
        cfg: &ServeConfig,
    ) -> Result<()> {
        if self.demoted {
            return Ok(());
        }
        if let Some(pool) = &self.pool {
            if self.default_sized {
                let per_seq = dims.seq_len.div_ceil(self.block_tokens);
                let capacity = cfg.max_batch * per_seq;
                if capacity > self.capacity {
                    pool.lock().expect("KV pool poisoned").grow_capacity(capacity);
                    self.capacity = capacity;
                }
            }
            return Ok(());
        }
        let Some((n_layers, d_model)) = decoder.kv_layout() else {
            self.demoted = true;
            return Ok(());
        };
        let per_seq = dims.seq_len.div_ceil(cfg.block_tokens);
        let capacity = cfg.max_kv_blocks.unwrap_or(cfg.max_batch * per_seq).max(1);
        self.pool = Some(KvBlockPool::shared(
            n_layers,
            d_model,
            cfg.block_tokens,
            capacity,
        )?);
        self.capacity = capacity;
        self.block_tokens = cfg.block_tokens;
        self.default_sized = cfg.max_kv_blocks.is_none();
        Ok(())
    }

    /// Worst-case blocks a request needs (0 in contiguous mode).
    pub(crate) fn blocks_needed(&self, prompt_len: usize, max_new: usize, seq_len: usize) -> usize {
        if self.pool.is_none() {
            return 0;
        }
        (prompt_len + max_new).min(seq_len).div_ceil(self.block_tokens)
    }

    pub(crate) fn can_reserve(&self, need: usize) -> bool {
        self.pool.is_none() || need <= self.capacity - self.reserved
    }

    /// Whether `need` could ever be reserved, even with the pool idle.
    /// A request failing this is never admittable — submission rejects
    /// it at the door instead of queueing it forever.
    pub(crate) fn can_ever_fit(&self, need: usize) -> bool {
        self.pool.is_none() || need <= self.capacity
    }

    pub(crate) fn reserve(&mut self, need: usize) {
        self.reserved += need;
    }

    pub(crate) fn release(&mut self, need: usize) {
        self.reserved = self.reserved.saturating_sub(need);
    }

    pub(crate) fn stats(&self) -> KvPoolStats {
        match &self.pool {
            Some(p) => p.lock().expect("KV pool poisoned").stats(),
            None => KvPoolStats::default(),
        }
    }
}

impl Server<'_> {
    /// Page `name`'s decoder back in if it was evicted, stamp its LRU
    /// clock, and re-enforce the residency cap.
    pub(crate) fn ensure_resident(&mut self, name: &str) -> Result<()> {
        let needs_build = self
            .adapters
            .get(name)
            .with_context(|| format!("unknown adapter '{name}'"))?
            .decoder
            .is_none();
        if needs_build {
            let a = self.adapters.get(name).expect("checked above");
            // Merged artifacts rebuild against their private base; its
            // buffer cache makes the page-in upload-free too.
            let base = a.base.as_deref().unwrap_or(&self.base);
            let decoder = build_decoder(self.engine, base, &a.manifest, &a.trainables)?;
            let a = self.adapters.get_mut(name).expect("checked above");
            a.decoder = Some(decoder);
            a.page_ins += 1;
            self.metrics.adapter_page_ins += 1;
        }
        self.pager
            .touch(self.adapters.get_mut(name).expect("checked above"));
        // The adapter being paged in is about to be used but is not yet
        // pinned by an active sequence — exempt it from eviction so the
        // cap can't tear down the decoder this very call produced.
        self.enforce_residency(Some(name));
        Ok(())
    }

    /// Evict least-recently-used decoders until at or under the cap.
    /// Adapters with active sequences are pinned, and `keep` (the
    /// adapter whose page-in triggered enforcement, admitted but not
    /// yet pinned) is never a victim; if everything resident is pinned
    /// the cap is temporarily exceeded rather than tearing down
    /// in-flight sessions.
    pub(crate) fn enforce_residency(&mut self, keep: Option<&str>) {
        let resident = self.resident_adapters();
        self.metrics.peak_resident = self.metrics.peak_resident.max(resident);
        let Some(cap) = self.pager.max_resident() else {
            return;
        };
        let cap = cap.max(1);
        let mut resident = resident;
        while resident > cap {
            let victim = self
                .adapters
                .iter()
                .filter(|(n, a)| {
                    a.decoder.is_some() && a.active_seqs == 0 && keep != Some(n.as_str())
                })
                .min_by_key(|(_, a)| a.last_used)
                .map(|(n, _)| n.clone());
            let Some(name) = victim else {
                break;
            };
            self.adapters.get_mut(&name).expect("victim exists").decoder = None;
            self.metrics.adapter_evictions += 1;
            resident -= 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::state::AdapterState;

    #[test]
    fn default_sized_pool_grows_for_longer_seq_len_adapters() {
        // Regression: the pool used to be sized once, from the first
        // attached adapter's seq_len — a later adapter with a longer
        // seq_len had full-length requests that could never fit.
        let engine = Engine::reference();
        let base = BaseModel::for_preset(&engine, "tiny", 7, None).unwrap();
        let manifest = Manifest::builtin("tiny_oft_v2").unwrap();
        let state = AdapterState::init(&manifest, 7, None).unwrap();
        let decoder = build_decoder(&engine, &base, &manifest, &state.tr).unwrap();
        let cfg = ServeConfig::new(2);
        let per_seq = manifest.model.seq_len.div_ceil(cfg.block_tokens);

        let mut kv = KvBudget::new();
        kv.ensure_pool(&decoder, &manifest.model, &cfg).unwrap();
        assert_eq!(kv.capacity(), 2 * per_seq);
        let mut longer = manifest.model;
        longer.seq_len *= 2;
        kv.ensure_pool(&decoder, &longer, &cfg).unwrap();
        assert_eq!(kv.capacity(), 4 * per_seq, "default sizing covers the max seq_len");
        assert!(kv.can_ever_fit(2 * per_seq));

        // An explicit max_kv_blocks stays a hard cap; oversized requests
        // are rejected at submit instead (RejectReason::KvExceedsPool).
        let mut cfg = ServeConfig::new(2);
        cfg.max_kv_blocks = Some(per_seq);
        let mut kv = KvBudget::new();
        kv.ensure_pool(&decoder, &manifest.model, &cfg).unwrap();
        kv.ensure_pool(&decoder, &longer, &cfg).unwrap();
        assert_eq!(kv.capacity(), per_seq);
        assert!(!kv.can_ever_fit(2 * per_seq));
    }
}
