//! Multi-tenant adapter serving over one shared [`BaseModel`].
//!
//! The paper's input-centric design leaves the (quantized) base weights
//! untouched, so one frozen base can serve many adapters at once — the
//! same property BOFT/HOFT exploit. This module is that runtime: N
//! named adapters (any mix of the registered PEFT methods) attach to a
//! single engine-resident base, requests enter a bounded queue with
//! reject-with-reason admission control, and a continuous batching loop
//! interleaves one KV-cached decode step per in-flight sequence per
//! tick — heterogeneous ticks serve many adapters at once.
//!
//! Two resources are paged so the server scales past "everything
//! resident forever":
//!
//! * **KV memory** — sequences draw fixed-size token blocks from one
//!   shared free-list [`KvBlockPool`] ([`alloc`]) instead of each
//!   owning a contiguous seq_len cache; total KV is bounded by the pool
//!   capacity however many sessions come and go, and admission reserves
//!   worst-case blocks up front so a mid-decode step can never fail.
//!   The contiguous session stays available as [`KvMode::Contiguous`] —
//!   the bitwise oracle the paged path is tested against, the way
//!   `dequantize()` backs `tensor::fused`.
//! * **Adapter state** — resolved decoders are LRU-paged under a
//!   residency cap ([`alloc::AdapterPager`]); an evicted adapter's
//!   decoder is rebuilt on its next request from retained trainables +
//!   the base's cached buffers, so hot-swap never drops or re-uploads
//!   the shared base (`Engine::upload_count()` stays flat).
//!
//! The loop is deterministic and single-threaded: scheduling policy is
//! testable without timing races, and per-request / per-adapter
//! latency + throughput metrics come out of the same code path the
//! `serve` CLI and the serving bench use. Incremental output streams as
//! [`TokenEvent`]s (see [`Server::take_events`]).

mod alloc;
mod scheduler;
mod session;

use std::collections::{BTreeMap, VecDeque};
use std::fmt;
use std::sync::Arc;

use anyhow::{anyhow, ensure, Context, Result};

use crate::coordinator::manifest::Manifest;
use crate::coordinator::state::{AdapterState, BaseModel};
use crate::coordinator::Checkpoint;
use crate::runtime::{Engine, KvPoolStats, Value};
use crate::util::timer::Timer;

use self::alloc::{Adapter, AdapterPager, KvBudget};
use self::session::Active;

/// One decode request against a named adapter.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub adapter: String,
    pub prompt: Vec<i32>,
    pub max_new: usize,
}

/// A finished request with its generated tokens and timing.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub adapter: String,
    pub prompt_len: usize,
    /// Prompt tokens dropped at admission because the prompt exceeded
    /// the model's seq_len (0 = nothing was cut). Callers must check
    /// this — the decode ran against a shortened prompt.
    pub truncated_tokens: usize,
    pub tokens: Vec<i32>,
    /// Seconds spent waiting in the queue before admission.
    pub queued_secs: f64,
    /// Submit → first generated token.
    pub ttft_secs: f64,
    /// Submit → completion.
    pub latency_secs: f64,
}

/// Why `try_submit` turned a request away at the door.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// The bounded queue is at capacity; retry after draining.
    QueueFull { limit: usize },
    UnknownAdapter { name: String },
    EmptyPrompt,
    /// The request's worst-case KV reservation exceeds the entire block
    /// pool — no amount of waiting can ever admit it. Shrink the prompt
    /// or `max_new`, or raise `max_kv_blocks`.
    KvExceedsPool { need_blocks: usize, capacity_blocks: usize },
}

impl fmt::Display for RejectReason {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RejectReason::QueueFull { limit } => {
                write!(f, "queue full ({limit} requests)")
            }
            RejectReason::UnknownAdapter { name } => {
                write!(f, "unknown adapter '{name}'")
            }
            RejectReason::EmptyPrompt => write!(f, "empty prompt"),
            RejectReason::KvExceedsPool { need_blocks, capacity_blocks } => write!(
                f,
                "worst-case KV need of {need_blocks} block(s) exceeds the \
                 pool capacity of {capacity_blocks}"
            ),
        }
    }
}

/// Outcome of [`Server::try_submit`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Submission {
    Accepted { id: u64 },
    Rejected(RejectReason),
}

/// One incrementally streamed token (drain via [`Server::take_events`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TokenEvent {
    pub request_id: u64,
    pub adapter: String,
    pub token: i32,
    /// 0-based index within the request's generated stream.
    pub index: usize,
    /// Set on the final token of the request.
    pub last: bool,
}

/// Where sequences keep their KV rows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvMode {
    /// Fixed-size blocks from the shared free-list pool (the default).
    Paged,
    /// One private contiguous seq_len cache per session — the PR-2
    /// path, kept as the bitwise oracle for the paged scheduler.
    Contiguous,
}

/// Serving policy knobs (see [`Server::with_config`]).
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Maximum simultaneously active sequences.
    pub max_batch: usize,
    /// Bounded queue depth; submissions beyond it are rejected.
    pub max_queue: usize,
    pub kv: KvMode,
    /// Tokens per KV block (paged mode).
    pub block_tokens: usize,
    /// KV pool capacity in blocks. `None` sizes it for `max_batch`
    /// full-length sequences — the same worst case the contiguous
    /// path always pays.
    pub max_kv_blocks: Option<usize>,
    /// Resident-decoder cap for adapter LRU paging; `None` = all
    /// attached adapters stay resident (the pre-paging behavior).
    pub max_resident: Option<usize>,
}

impl ServeConfig {
    pub fn new(max_batch: usize) -> ServeConfig {
        ServeConfig {
            max_batch: max_batch.max(1),
            max_queue: 1024,
            kv: KvMode::Paged,
            block_tokens: 16,
            max_kv_blocks: None,
            max_resident: None,
        }
    }
}

/// Aggregate counters for one adapter.
#[derive(Clone, Debug, Default)]
pub struct AdapterMetrics {
    pub requests: u64,
    pub tokens_out: u64,
    pub sum_latency_secs: f64,
    pub sum_ttft_secs: f64,
    /// Seconds spent inside this adapter's decode steps.
    pub decode_secs: f64,
}

impl AdapterMetrics {
    pub fn mean_latency_secs(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.sum_latency_secs / self.requests as f64
        }
    }

    pub fn mean_ttft_secs(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.sum_ttft_secs / self.requests as f64
        }
    }

    /// Generated tokens per second of this adapter's decode time.
    pub fn tokens_per_sec(&self) -> f64 {
        if self.decode_secs <= 0.0 {
            0.0
        } else {
            self.tokens_out as f64 / self.decode_secs
        }
    }
}

/// Server-wide counters.
#[derive(Clone, Debug, Default)]
pub struct ServeMetrics {
    pub per_adapter: BTreeMap<String, AdapterMetrics>,
    pub total_requests: u64,
    pub total_tokens: u64,
    /// Wall-clock seconds inside `run_until_idle` / `run_step`.
    pub wall_secs: f64,
    /// Highest number of simultaneously active sequences observed.
    pub peak_active: usize,
    /// Submissions turned away because the queue was at capacity.
    pub rejected_queue_full: u64,
    /// Requests whose prompt was cut to seq_len at admission.
    pub truncated_requests: u64,
    /// Total prompt tokens dropped by truncation.
    pub truncated_tokens: u64,
    /// Decoders rebuilt after an LRU eviction (adapter page-ins).
    pub adapter_page_ins: u64,
    /// Decoders dropped by the residency cap.
    pub adapter_evictions: u64,
    /// Highest simultaneously resident decoder count observed.
    pub peak_resident: usize,
    /// KV block-pool occupancy (all-zero in contiguous mode).
    pub kv: KvPoolStats,
}

impl ServeMetrics {
    /// Aggregate generated-token throughput over the serving wall time.
    pub fn tokens_per_sec(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            0.0
        } else {
            self.total_tokens as f64 / self.wall_secs
        }
    }
}

/// A batched multi-tenant decode server over one shared base.
pub struct Server<'e> {
    engine: &'e Engine,
    base: Arc<BaseModel>,
    cfg: ServeConfig,
    adapters: BTreeMap<String, Adapter>,
    pager: AdapterPager,
    kv: KvBudget,
    queue: VecDeque<(Request, Timer)>,
    active: Vec<Active>,
    events: Vec<TokenEvent>,
    next_id: u64,
    metrics: ServeMetrics,
}

impl<'e> Server<'e> {
    /// A server with default policy: paged KV, bounded queue, no
    /// residency cap.
    pub fn new(engine: &'e Engine, base: Arc<BaseModel>, max_batch: usize) -> Server<'e> {
        Server::with_config(engine, base, ServeConfig::new(max_batch))
    }

    pub fn with_config(engine: &'e Engine, base: Arc<BaseModel>, cfg: ServeConfig) -> Server<'e> {
        let mut cfg = cfg;
        cfg.max_batch = cfg.max_batch.max(1);
        cfg.max_queue = cfg.max_queue.max(1);
        cfg.block_tokens = cfg.block_tokens.max(1);
        Server {
            engine,
            base,
            cfg,
            adapters: BTreeMap::new(),
            pager: AdapterPager::new(cfg.max_resident),
            kv: KvBudget::new(),
            queue: VecDeque::new(),
            active: Vec::new(),
            events: Vec::new(),
            next_id: 0,
            metrics: ServeMetrics::default(),
        }
    }

    pub fn base(&self) -> Arc<BaseModel> {
        Arc::clone(&self.base)
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// The KV mode requests actually decode under (a backend without a
    /// paged path demotes [`KvMode::Paged`] to contiguous at first
    /// attach).
    pub fn kv_mode(&self) -> KvMode {
        if self.kv.is_paged() {
            KvMode::Paged
        } else {
            KvMode::Contiguous
        }
    }

    /// Attach a named adapter with explicit trainable values (e.g. a
    /// finetuned trainer's weights). Fixed inputs come from the shared
    /// base — no base re-upload — and the trainables are retained so an
    /// LRU-evicted decoder can be rebuilt without the caller.
    pub fn add_adapter(&mut self, name: &str, manifest: Manifest, trainables: &[Value]) -> Result<()> {
        ensure!(
            !self.adapters.contains_key(name),
            "adapter '{name}' already registered"
        );
        ensure!(
            trainables.len() == manifest.trainable.len(),
            "adapter '{name}': {} trainable values for {} manifest specs",
            trainables.len(),
            manifest.trainable.len()
        );
        let decoder = alloc::build_decoder(self.engine, &self.base, &manifest, trainables)?;
        if self.cfg.kv == KvMode::Paged {
            self.kv.ensure_pool(&decoder, &manifest.model, &self.cfg)?;
        }
        self.metrics
            .per_adapter
            .insert(name.to_string(), AdapterMetrics::default());
        self.adapters.insert(
            name.to_string(),
            Adapter::new(manifest, trainables.to_vec(), decoder),
        );
        self.pager.touch(self.adapters.get_mut(name).expect("just inserted"));
        self.enforce_residency(None);
        Ok(())
    }

    /// Attach a named adapter initialized from its bundle's init specs
    /// (checkpoint values win) — the serving analogue of
    /// `Trainer::with_checkpoint`. A checkpoint whose base weights
    /// disagree with the shared base is rejected rather than silently
    /// decoding against the wrong frozen weights.
    pub fn add_adapter_init(
        &mut self,
        name: &str,
        manifest: Manifest,
        seed: u64,
        ckpt: Option<&Checkpoint>,
    ) -> Result<()> {
        if let Some(c) = ckpt {
            self.base.ensure_checkpoint_matches(&manifest, c)?;
        }
        let state = AdapterState::init(&manifest, seed, ckpt)?;
        self.add_adapter(name, manifest, &state.tr)
    }

    /// Attach a merged deployable artifact (`repro merge`) as a
    /// zero-trainable resident. The artifact's parameters become a
    /// private base uploaded once here; LRU page-ins rebuild the
    /// decoder from its cached buffers, so `Engine::upload_count()`
    /// stays flat across page-ins exactly as for live adapters.
    pub fn add_artifact(&mut self, name: &str, art: &crate::artifact::Artifact) -> Result<()> {
        ensure!(
            !self.adapters.contains_key(name),
            "adapter '{name}' already registered"
        );
        ensure!(
            art.preset == self.base.preset,
            "artifact '{name}' was merged for preset '{}', server base is '{}'",
            art.preset,
            self.base.preset
        );
        let manifest = Manifest::builtin(&format!("{}_none", art.preset))
            .with_context(|| format!("preset '{}' has no builtin base contract", art.preset))?;
        for spec in &manifest.frozen {
            ensure!(
                art.params.contains_key(&spec.name),
                "artifact '{name}' lacks base parameter '{}'",
                spec.name
            );
        }
        let base =
            BaseModel::from_manifest(self.engine, &manifest, art.seed, Some(&art.params))?;
        let decoder = alloc::build_decoder(self.engine, &base, &manifest, &[])?;
        if self.cfg.kv == KvMode::Paged {
            self.kv.ensure_pool(&decoder, &manifest.model, &self.cfg)?;
        }
        self.metrics
            .per_adapter
            .insert(name.to_string(), AdapterMetrics::default());
        self.adapters
            .insert(name.to_string(), Adapter::merged(manifest, base, decoder));
        self.pager.touch(self.adapters.get_mut(name).expect("just inserted"));
        self.enforce_residency(None);
        Ok(())
    }

    pub fn adapter_names(&self) -> Vec<String> {
        self.adapters.keys().cloned().collect()
    }

    /// Attached merged-artifact residents (each carries a private
    /// merged base; see [`crate::memmodel`] for how they are priced).
    pub fn merged_adapters(&self) -> usize {
        self.adapters.values().filter(|a| a.is_merged()).count()
    }

    /// Adapters whose decoder is currently resident (LRU paging keeps
    /// this at or under the configured cap once nothing pins them).
    pub fn resident_adapters(&self) -> usize {
        self.adapters.values().filter(|a| a.decoder.is_some()).count()
    }

    /// Vocab of a registered adapter (for prompt construction).
    pub fn vocab_of(&self, adapter: &str) -> Result<usize> {
        Ok(self
            .adapters
            .get(adapter)
            .with_context(|| format!("unknown adapter '{adapter}'"))?
            .manifest
            .model
            .vocab)
    }

    /// Enqueue a request; turns rejections into errors (see
    /// [`Server::try_submit`] for the non-erroring form).
    pub fn submit(&mut self, adapter: &str, prompt: Vec<i32>, max_new: usize) -> Result<u64> {
        match self.try_submit(adapter, prompt, max_new) {
            Submission::Accepted { id } => Ok(id),
            Submission::Rejected(r) => Err(anyhow!("request rejected: {r}")),
        }
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn active(&self) -> usize {
        self.active.len()
    }

    pub fn metrics(&self) -> &ServeMetrics {
        &self.metrics
    }

    /// Drain the tokens streamed since the last call (emitted in decode
    /// order — the incremental output a gateway would flush to clients).
    pub fn take_events(&mut self) -> Vec<TokenEvent> {
        std::mem::take(&mut self.events)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_rates() {
        let mut m = AdapterMetrics::default();
        assert_eq!(m.mean_latency_secs(), 0.0);
        assert_eq!(m.tokens_per_sec(), 0.0);
        m.requests = 2;
        m.tokens_out = 20;
        m.sum_latency_secs = 1.0;
        m.decode_secs = 0.5;
        assert_eq!(m.mean_latency_secs(), 0.5);
        assert_eq!(m.tokens_per_sec(), 40.0);
    }

    #[test]
    fn submit_requires_known_adapter() {
        let engine = Engine::reference();
        let base = BaseModel::for_preset(&engine, "tiny", 7, None).unwrap();
        let mut srv = Server::new(&engine, base, 4);
        assert!(srv.submit("ghost", vec![1], 4).is_err());
        assert_eq!(
            srv.try_submit("ghost", vec![1], 4),
            Submission::Rejected(RejectReason::UnknownAdapter { name: "ghost".into() })
        );
        assert!(srv.run_until_idle().is_err(), "no adapters registered");
    }

    #[test]
    fn bounded_queue_rejects_with_reason() {
        let engine = Engine::reference();
        let base = BaseModel::for_preset(&engine, "tiny", 7, None).unwrap();
        let mut cfg = ServeConfig::new(2);
        cfg.max_queue = 2;
        let mut srv = Server::with_config(&engine, base, cfg);
        srv.add_adapter_init("a", Manifest::builtin("tiny_oft_v2").unwrap(), 7, None)
            .unwrap();
        assert!(matches!(srv.try_submit("a", vec![1], 2), Submission::Accepted { .. }));
        assert!(matches!(srv.try_submit("a", vec![2], 2), Submission::Accepted { .. }));
        let r = srv.try_submit("a", vec![3], 2);
        assert_eq!(r, Submission::Rejected(RejectReason::QueueFull { limit: 2 }));
        assert_eq!(
            srv.try_submit("a", vec![], 2),
            Submission::Rejected(RejectReason::EmptyPrompt)
        );
        assert_eq!(srv.metrics().rejected_queue_full, 1);
        // The erroring form reports the same reason.
        let err = srv.submit("a", vec![4], 2).unwrap_err().to_string();
        assert!(err.contains("queue full"), "got: {err}");
        srv.run_until_idle().unwrap();
        assert!(matches!(srv.try_submit("a", vec![5], 2), Submission::Accepted { .. }));
    }

    // End-to-end serving tests (base sharing, paged-vs-contiguous
    // equality, continuous batching, edge cases) live in
    // rust/tests/serving.rs.
}
