//! Multi-tenant adapter serving over one shared [`BaseModel`].
//!
//! The paper's input-centric design leaves the (quantized) base weights
//! untouched, so one frozen base can serve many adapters at once — the
//! same property BOFT/HOFT exploit. This module is that runtime: N
//! named adapters (any mix of the registered PEFT methods) attach to a single
//! engine-resident base, requests enter a FIFO queue, and a continuous
//! batching loop interleaves one KV-cached decode step per in-flight
//! sequence per tick, admitting queued requests as slots free up.
//!
//! The loop is deterministic and single-threaded: scheduling policy is
//! testable without timing races, and per-request / per-adapter
//! latency + throughput metrics come out of the same code path the
//! `serve` CLI and the serving bench use.

use std::collections::{BTreeMap, VecDeque};
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::manifest::Manifest;
use crate::coordinator::state::{AdapterState, BaseModel};
use crate::coordinator::Checkpoint;
use crate::data::tokenizer::EOS;
use crate::runtime::{Buffer, DecodeSession, Decoder, Engine, Value};
use crate::util::argmax;
use crate::util::timer::Timer;

/// One decode request against a named adapter.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub adapter: String,
    pub prompt: Vec<i32>,
    pub max_new: usize,
}

/// A finished request with its generated tokens and timing.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub adapter: String,
    pub prompt_len: usize,
    pub tokens: Vec<i32>,
    /// Seconds spent waiting in the queue before admission.
    pub queued_secs: f64,
    /// Submit → first generated token.
    pub ttft_secs: f64,
    /// Submit → completion.
    pub latency_secs: f64,
}

/// Aggregate counters for one adapter.
#[derive(Clone, Debug, Default)]
pub struct AdapterMetrics {
    pub requests: u64,
    pub tokens_out: u64,
    pub sum_latency_secs: f64,
    pub sum_ttft_secs: f64,
    /// Seconds spent inside this adapter's decode steps.
    pub decode_secs: f64,
}

impl AdapterMetrics {
    pub fn mean_latency_secs(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.sum_latency_secs / self.requests as f64
        }
    }

    pub fn mean_ttft_secs(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.sum_ttft_secs / self.requests as f64
        }
    }

    /// Generated tokens per second of this adapter's decode time.
    pub fn tokens_per_sec(&self) -> f64 {
        if self.decode_secs <= 0.0 {
            0.0
        } else {
            self.tokens_out as f64 / self.decode_secs
        }
    }
}

/// Server-wide counters.
#[derive(Clone, Debug, Default)]
pub struct ServeMetrics {
    pub per_adapter: BTreeMap<String, AdapterMetrics>,
    pub total_requests: u64,
    pub total_tokens: u64,
    /// Wall-clock seconds inside `run_until_idle`.
    pub wall_secs: f64,
    /// Highest number of simultaneously active sequences observed.
    pub peak_active: usize,
}

impl ServeMetrics {
    /// Aggregate generated-token throughput over the serving wall time.
    pub fn tokens_per_sec(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            0.0
        } else {
            self.total_tokens as f64 / self.wall_secs
        }
    }
}

struct Adapter {
    manifest: Manifest,
    decoder: Decoder,
}

struct Active {
    req: Request,
    sess: DecodeSession,
    seq_len: usize,
    total_len: usize,
    generated: Vec<i32>,
    last_logits: Vec<f32>,
    queued_secs: f64,
    ttft_secs: Option<f64>,
    submitted: Timer,
}

/// A batched multi-tenant decode server over one shared base.
pub struct Server<'e> {
    engine: &'e Engine,
    base: Arc<BaseModel>,
    adapters: BTreeMap<String, Adapter>,
    queue: VecDeque<(Request, Timer)>,
    active: Vec<Active>,
    /// Maximum simultaneously active sequences.
    pub max_batch: usize,
    next_id: u64,
    metrics: ServeMetrics,
}

impl<'e> Server<'e> {
    pub fn new(engine: &'e Engine, base: Arc<BaseModel>, max_batch: usize) -> Server<'e> {
        Server {
            engine,
            base,
            adapters: BTreeMap::new(),
            queue: VecDeque::new(),
            active: Vec::new(),
            max_batch: max_batch.max(1),
            next_id: 0,
            metrics: ServeMetrics::default(),
        }
    }

    pub fn base(&self) -> Arc<BaseModel> {
        Arc::clone(&self.base)
    }

    /// Attach a named adapter with explicit trainable values (e.g. a
    /// finetuned trainer's weights). Fixed inputs come from the shared
    /// base — no base re-upload.
    pub fn add_adapter(&mut self, name: &str, manifest: Manifest, trainables: &[Value]) -> Result<()> {
        ensure!(
            !self.adapters.contains_key(name),
            "adapter '{name}' already registered"
        );
        ensure!(
            trainables.len() == manifest.trainable.len(),
            "adapter '{name}': {} trainable values for {} manifest specs",
            trainables.len(),
            manifest.trainable.len()
        );
        let fixed = self.base.fixed_for(self.engine, &manifest)?;
        let tr: Vec<&Value> = trainables.iter().collect();
        let fixed_refs: Vec<&Buffer> = fixed.iter().map(|a| a.as_ref()).collect();
        let decoder = self.engine.load_decoder(&manifest, &tr, &fixed_refs)?;
        self.metrics
            .per_adapter
            .insert(name.to_string(), AdapterMetrics::default());
        self.adapters.insert(
            name.to_string(),
            Adapter { manifest, decoder },
        );
        Ok(())
    }

    /// Attach a named adapter initialized from its bundle's init specs
    /// (checkpoint values win) — the serving analogue of
    /// `Trainer::with_checkpoint`. A checkpoint whose base weights
    /// disagree with the shared base is rejected rather than silently
    /// decoding against the wrong frozen weights.
    pub fn add_adapter_init(
        &mut self,
        name: &str,
        manifest: Manifest,
        seed: u64,
        ckpt: Option<&Checkpoint>,
    ) -> Result<()> {
        if let Some(c) = ckpt {
            self.base.ensure_checkpoint_matches(&manifest, c)?;
        }
        let state = AdapterState::init(&manifest, seed, ckpt)?;
        self.add_adapter(name, manifest, &state.tr)
    }

    pub fn adapter_names(&self) -> Vec<String> {
        self.adapters.keys().cloned().collect()
    }

    /// Vocab of a registered adapter (for prompt construction).
    pub fn vocab_of(&self, adapter: &str) -> Result<usize> {
        Ok(self
            .adapters
            .get(adapter)
            .with_context(|| format!("unknown adapter '{adapter}'"))?
            .manifest
            .model
            .vocab)
    }

    /// Enqueue a request (FIFO); returns its id.
    pub fn submit(&mut self, adapter: &str, prompt: Vec<i32>, max_new: usize) -> Result<u64> {
        ensure!(
            self.adapters.contains_key(adapter),
            "unknown adapter '{adapter}' (registered: {})",
            self.adapter_names().join(", ")
        );
        ensure!(!prompt.is_empty(), "empty prompt");
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back((
            Request {
                id,
                adapter: adapter.to_string(),
                prompt,
                max_new,
            },
            Timer::start(),
        ));
        Ok(id)
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn active(&self) -> usize {
        self.active.len()
    }

    pub fn metrics(&self) -> &ServeMetrics {
        &self.metrics
    }

    /// Admit queued requests into free batch slots (FIFO), prefilling
    /// each prompt through a fresh KV session. Requests that can emit
    /// nothing (`max_new == 0`, or a prompt already filling seq_len)
    /// complete immediately with no tokens — the same empty result
    /// `Trainer::decode_greedy` returns for them.
    fn admit(&mut self) -> Result<Vec<Response>> {
        let mut done = Vec::new();
        while self.active.len() < self.max_batch {
            let Some((req, submitted)) = self.queue.pop_front() else {
                break;
            };
            let queued_secs = submitted.secs();
            let adapter = self
                .adapters
                .get(&req.adapter)
                .with_context(|| format!("unknown adapter '{}'", req.adapter))?;
            let seq_len = adapter.decoder.max_positions();
            let mut prompt = req.prompt.clone();
            prompt.truncate(seq_len);
            if req.max_new == 0 || prompt.len() >= seq_len {
                let latency = submitted.secs();
                let am = self
                    .metrics
                    .per_adapter
                    .get_mut(&req.adapter)
                    .expect("metrics registered with adapter");
                am.requests += 1;
                am.sum_latency_secs += latency;
                am.sum_ttft_secs += latency;
                self.metrics.total_requests += 1;
                done.push(Response {
                    id: req.id,
                    adapter: req.adapter,
                    prompt_len: prompt.len(),
                    tokens: Vec::new(),
                    queued_secs,
                    ttft_secs: latency,
                    latency_secs: latency,
                });
                continue;
            }
            let mut sess = adapter.decoder.begin()?;
            let t0 = Timer::start();
            let mut last_logits = Vec::new();
            for &id in &prompt {
                last_logits = sess.step(id)?;
            }
            let prefill_secs = t0.secs();
            self.metrics
                .per_adapter
                .get_mut(&req.adapter)
                .expect("metrics registered with adapter")
                .decode_secs += prefill_secs;
            let total_len = prompt.len();
            self.active.push(Active {
                req,
                sess,
                seq_len,
                total_len,
                generated: Vec::new(),
                last_logits,
                queued_secs,
                ttft_secs: None,
                submitted,
            });
        }
        self.metrics.peak_active = self.metrics.peak_active.max(self.active.len());
        Ok(done)
    }

    /// One scheduler tick: every active sequence emits one token (and
    /// steps its KV cache unless it just finished). Returns responses
    /// for sequences that completed this tick.
    fn tick(&mut self) -> Result<Vec<Response>> {
        let mut done = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            let a = &mut self.active[i];
            let adapter_name = a.req.adapter.clone();
            let next = argmax(&a.last_logits) as i32;
            a.generated.push(next);
            a.total_len += 1;
            if a.ttft_secs.is_none() {
                a.ttft_secs = Some(a.submitted.secs());
            }
            let finished = next == EOS
                || a.generated.len() >= a.req.max_new
                || a.total_len >= a.seq_len;
            let step_secs = if finished {
                0.0
            } else {
                let t0 = Timer::start();
                a.last_logits = a.sess.step(next)?;
                t0.secs()
            };
            self.metrics.total_tokens += 1;
            let am = self
                .metrics
                .per_adapter
                .get_mut(&adapter_name)
                .expect("metrics registered with adapter");
            am.tokens_out += 1;
            am.decode_secs += step_secs;
            if finished {
                let a = self.active.remove(i);
                let latency = a.submitted.secs();
                let am = self
                    .metrics
                    .per_adapter
                    .get_mut(&adapter_name)
                    .expect("metrics registered with adapter");
                am.requests += 1;
                am.sum_latency_secs += latency;
                am.sum_ttft_secs += a.ttft_secs.unwrap_or(latency);
                self.metrics.total_requests += 1;
                done.push(Response {
                    id: a.req.id,
                    adapter: a.req.adapter,
                    prompt_len: a.req.prompt.len().min(a.seq_len),
                    tokens: a.generated,
                    queued_secs: a.queued_secs,
                    ttft_secs: a.ttft_secs.unwrap_or(latency),
                    latency_secs: latency,
                });
                continue; // element removed; same index is the next seq
            }
            i += 1;
        }
        Ok(done)
    }

    /// Drain queue + in-flight work to completion; returns responses in
    /// completion order.
    pub fn run_until_idle(&mut self) -> Result<Vec<Response>> {
        if self.adapters.is_empty() {
            bail!("no adapters registered");
        }
        let wall = Timer::start();
        let mut responses = Vec::new();
        loop {
            responses.extend(self.admit()?);
            if self.active.is_empty() {
                break;
            }
            responses.extend(self.tick()?);
        }
        self.metrics.wall_secs += wall.secs();
        Ok(responses)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_rates() {
        let mut m = AdapterMetrics::default();
        assert_eq!(m.mean_latency_secs(), 0.0);
        assert_eq!(m.tokens_per_sec(), 0.0);
        m.requests = 2;
        m.tokens_out = 20;
        m.sum_latency_secs = 1.0;
        m.decode_secs = 0.5;
        assert_eq!(m.mean_latency_secs(), 0.5);
        assert_eq!(m.tokens_per_sec(), 40.0);
    }

    #[test]
    fn submit_requires_known_adapter() {
        let engine = Engine::reference();
        let base = BaseModel::for_preset(&engine, "tiny", 7, None).unwrap();
        let mut srv = Server::new(&engine, base, 4);
        assert!(srv.submit("ghost", vec![1], 4).is_err());
        assert!(srv.run_until_idle().is_err(), "no adapters registered");
    }

    // End-to-end serving tests (base sharing, KV-vs-reforward equality,
    // continuous batching) live in rust/tests/serving.rs.
}
