//! The continuous-batching scheduler: bounded-queue submission,
//! reservation-gated admission, and the heterogeneous decode tick that
//! advances every active sequence (any mix of adapters) one token.

use anyhow::{ensure, Result};

use crate::data::tokenizer::EOS;
use crate::util::argmax;
use crate::util::timer::Timer;

use super::session::Active;
use super::{RejectReason, Request, Response, Server, Submission, TokenEvent};

impl Server<'_> {
    /// Enqueue a request if the server will take it; rejections carry
    /// the reason instead of an error (admission control, not failure).
    pub fn try_submit(&mut self, adapter: &str, prompt: Vec<i32>, max_new: usize) -> Submission {
        let Some(seq_len) = self.adapters.get(adapter).map(|a| a.manifest.model.seq_len) else {
            return Submission::Rejected(RejectReason::UnknownAdapter {
                name: adapter.to_string(),
            });
        };
        if prompt.is_empty() {
            return Submission::Rejected(RejectReason::EmptyPrompt);
        }
        // Reject at the door what admission could never schedule: a
        // worst-case reservation larger than the whole pool would
        // otherwise sit in the queue forever (`admit` skips it on every
        // step, releases can never free enough).
        let prompt_use = prompt.len().min(seq_len);
        if max_new > 0 && prompt_use < seq_len {
            let need = self.kv.blocks_needed(prompt_use, max_new, seq_len);
            if !self.kv.can_ever_fit(need) {
                return Submission::Rejected(RejectReason::KvExceedsPool {
                    need_blocks: need,
                    capacity_blocks: self.kv.capacity(),
                });
            }
        }
        if self.queue.len() >= self.cfg.max_queue {
            self.metrics.rejected_queue_full += 1;
            return Submission::Rejected(RejectReason::QueueFull {
                limit: self.cfg.max_queue,
            });
        }
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back((
            Request {
                id,
                adapter: adapter.to_string(),
                prompt,
                max_new,
            },
            Timer::start(),
        ));
        Submission::Accepted { id }
    }

    /// Admit queued requests into free batch slots, prefilling each
    /// prompt through a fresh KV session. Admission is FIFO except that
    /// a request whose worst-case KV reservation doesn't fit yet is
    /// skipped (no head-of-line blocking on memory) and retried next
    /// step. Requests that can emit nothing (`max_new == 0`, or a
    /// prompt already filling seq_len) complete immediately with no
    /// tokens — the same empty result `Trainer::decode_greedy` returns
    /// for them.
    fn admit(&mut self) -> Result<Vec<Response>> {
        let mut done = Vec::new();
        let mut qi = 0;
        while self.active.len() < self.cfg.max_batch && qi < self.queue.len() {
            let (seq_len, prompt_use, orig_len, max_new) = {
                let (req, _) = &self.queue[qi];
                let seq_len = self
                    .adapters
                    .get(&req.adapter)
                    .expect("validated at submit; adapters are never detached")
                    .manifest
                    .model
                    .seq_len;
                (seq_len, req.prompt.len().min(seq_len), req.prompt.len(), req.max_new)
            };
            let emits_nothing = max_new == 0 || prompt_use >= seq_len;
            let need = if emits_nothing {
                0
            } else {
                self.kv.blocks_needed(prompt_use, max_new, seq_len)
            };
            if !self.kv.can_reserve(need) {
                qi += 1;
                continue;
            }
            let (req, submitted) = self.queue.remove(qi).expect("index bounded above");
            let queued_secs = submitted.secs();
            let truncated = orig_len - prompt_use;
            if truncated > 0 {
                self.metrics.truncated_requests += 1;
                self.metrics.truncated_tokens += truncated as u64;
            }
            if emits_nothing {
                let latency = submitted.secs();
                let am = self
                    .metrics
                    .per_adapter
                    .get_mut(&req.adapter)
                    .expect("metrics registered with adapter");
                am.requests += 1;
                am.sum_latency_secs += latency;
                am.sum_ttft_secs += latency;
                self.metrics.total_requests += 1;
                done.push(Response {
                    id: req.id,
                    adapter: req.adapter,
                    prompt_len: prompt_use,
                    truncated_tokens: truncated,
                    tokens: Vec::new(),
                    queued_secs,
                    ttft_secs: latency,
                    latency_secs: latency,
                });
                continue; // removal shifted the queue; qi already points at the next entry
            }
            self.ensure_resident(&req.adapter)?;
            let mut sess = {
                let adapter = self
                    .adapters
                    .get(&req.adapter)
                    .expect("validated at submit");
                let dec = adapter.decoder.as_ref().expect("just paged in");
                match self.kv.pool() {
                    Some(pool) => dec.begin_paged(pool)?,
                    None => dec.begin()?,
                }
            };
            let t0 = Timer::start();
            let mut last_logits = Vec::new();
            for &tid in req.prompt.iter().take(prompt_use) {
                last_logits = sess.step(tid)?;
            }
            let prefill_secs = t0.secs();
            self.metrics
                .per_adapter
                .get_mut(&req.adapter)
                .expect("metrics registered with adapter")
                .decode_secs += prefill_secs;
            self.kv.reserve(need);
            self.adapters
                .get_mut(&req.adapter)
                .expect("validated at submit")
                .active_seqs += 1;
            self.active.push(Active {
                req,
                sess,
                seq_len,
                total_len: prompt_use,
                truncated_tokens: truncated,
                kv_reserved: need,
                generated: Vec::new(),
                last_logits,
                queued_secs,
                ttft_secs: None,
                submitted,
            });
        }
        self.metrics.peak_active = self.metrics.peak_active.max(self.active.len());
        Ok(done)
    }

    /// One scheduler tick: every active sequence emits one token (and
    /// steps its KV cache unless it just finished). Returns responses
    /// for sequences that completed this tick.
    fn tick(&mut self) -> Result<Vec<Response>> {
        let mut done = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            let a = &mut self.active[i];
            let adapter_name = a.req.adapter.clone();
            let next = argmax(&a.last_logits) as i32;
            a.generated.push(next);
            a.total_len += 1;
            if a.ttft_secs.is_none() {
                a.ttft_secs = Some(a.submitted.secs());
            }
            let finished = next == EOS
                || a.generated.len() >= a.req.max_new
                || a.total_len >= a.seq_len;
            self.events.push(TokenEvent {
                request_id: a.req.id,
                adapter: adapter_name.clone(),
                token: next,
                index: a.generated.len() - 1,
                last: finished,
            });
            let step_secs = if finished {
                0.0
            } else {
                let t0 = Timer::start();
                a.last_logits = a.sess.step(next)?;
                t0.secs()
            };
            self.metrics.total_tokens += 1;
            let am = self
                .metrics
                .per_adapter
                .get_mut(&adapter_name)
                .expect("metrics registered with adapter");
            am.tokens_out += 1;
            am.decode_secs += step_secs;
            if finished {
                let a = self.active.remove(i);
                self.kv.release(a.kv_reserved);
                self.adapters
                    .get_mut(&adapter_name)
                    .expect("adapters are never detached")
                    .active_seqs -= 1;
                let resp = a.into_response();
                let am = self
                    .metrics
                    .per_adapter
                    .get_mut(&adapter_name)
                    .expect("metrics registered with adapter");
                am.requests += 1;
                am.sum_latency_secs += resp.latency_secs;
                am.sum_ttft_secs += resp.ttft_secs;
                self.metrics.total_requests += 1;
                done.push(resp);
                continue; // element removed; same index is the next seq
            }
            i += 1;
        }
        Ok(done)
    }

    /// Backstop for queued work that can never start: with nothing
    /// active there are no outstanding reservations, so a request still
    /// queued after `admit` has a worst-case KV need exceeding the
    /// whole pool. [`Server::try_submit`] rejects those at the door;
    /// this turns anything that slips past into an error instead of a
    /// silent livelock for step-at-a-time drivers.
    fn ensure_queue_serviceable(&self) -> Result<()> {
        ensure!(
            self.queue.is_empty(),
            "{} queued request(s) can never be admitted: worst-case KV \
             need exceeds the pool capacity of {} blocks",
            self.queue.len(),
            self.kv.capacity()
        );
        Ok(())
    }

    /// One admit + decode step — the incremental driver for callers
    /// that stream tokens (drain [`Server::take_events`] between
    /// steps). Returns requests that completed during the step.
    pub fn run_step(&mut self) -> Result<Vec<Response>> {
        ensure!(!self.adapters.is_empty(), "no adapters registered");
        let wall = Timer::start();
        let mut responses = self.admit()?;
        if self.active.is_empty() {
            // Nothing admitted and nothing running: a non-empty queue
            // here would never drain (`while queued > 0 { run_step }`
            // must error like `run_until_idle`, not spin forever).
            self.ensure_queue_serviceable()?;
        }
        responses.extend(self.tick()?);
        self.metrics.wall_secs += wall.secs();
        self.metrics.kv = self.kv.stats();
        Ok(responses)
    }

    /// Drain queue + in-flight work to completion; returns responses in
    /// completion order.
    pub fn run_until_idle(&mut self) -> Result<Vec<Response>> {
        ensure!(!self.adapters.is_empty(), "no adapters registered");
        let wall = Timer::start();
        let mut responses = Vec::new();
        loop {
            responses.extend(self.admit()?);
            if self.active.is_empty() {
                self.ensure_queue_serviceable()?;
                break;
            }
            responses.extend(self.tick()?);
        }
        self.metrics.wall_secs += wall.secs();
        self.metrics.kv = self.kv.stats();
        Ok(responses)
    }
}
