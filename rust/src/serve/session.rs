//! In-flight sequence state: one [`Active`] per admitted request, plus
//! its conversion into the final [`Response`].

use crate::runtime::DecodeSession;
use crate::util::timer::Timer;

use super::{Request, Response};

/// One admitted request mid-decode. The session owns the KV rows
/// (paged sessions return their blocks to the pool on drop); the
/// scheduler owns the reservation bookkeeping via `kv_reserved`.
pub(crate) struct Active {
    pub(crate) req: Request,
    pub(crate) sess: DecodeSession,
    pub(crate) seq_len: usize,
    /// Prompt + generated positions consumed so far.
    pub(crate) total_len: usize,
    /// Prompt tokens dropped at admission (over seq_len).
    pub(crate) truncated_tokens: usize,
    /// Blocks reserved against the KV budget (0 in contiguous mode).
    pub(crate) kv_reserved: usize,
    pub(crate) generated: Vec<i32>,
    pub(crate) last_logits: Vec<f32>,
    pub(crate) queued_secs: f64,
    pub(crate) ttft_secs: Option<f64>,
    pub(crate) submitted: Timer,
}

impl Active {
    /// Consume the sequence into its response (the KV session — and
    /// with it any pool blocks — drops here).
    pub(crate) fn into_response(self) -> Response {
        let latency = self.submitted.secs();
        Response {
            id: self.req.id,
            adapter: self.req.adapter,
            prompt_len: self.req.prompt.len().min(self.seq_len),
            truncated_tokens: self.truncated_tokens,
            tokens: self.generated,
            queued_secs: self.queued_secs,
            ttft_secs: self.ttft_secs.unwrap_or(latency),
            latency_secs: latency,
        }
    }
}
