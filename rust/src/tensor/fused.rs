//! Fused dequantize-matmul kernels: multiply against a *packed*
//! quantized weight by decoding it panel-by-panel into a small scratch
//! buffer — the full f32 weight matrix never exists.
//!
//! The kernels are deliberately accumulation-order-compatible with
//! [`Tensor::matmul`]: every output element accumulates over the
//! contraction index in ascending order with a single accumulator, so
//! `fused_matmul(x, ...)` reproduces `x.matmul(&w.dequantize())` bit
//! for bit whenever the decoder emits the exact `dequantize()` values.
//! This holds in *both* dispatch modes: the scalar paths here mirror
//! the scalar `matmul_panel`, and the SIMD paths share the exact
//! [`crate::tensor::simd::fma_row_block`] microkernel dense matmul
//! uses (`fused_matmul_t` transposes each decoded panel first so its
//! contraction runs through the same kernel). One row panel is decoded
//! per K-block (the same `KC` blocking as `matmul_panel`) and shared
//! read-only by the [`parallel_over_rows`] workers; each output row is
//! written by exactly one thread, so results are deterministic at every
//! thread count (and under `set_thread_cap`, which data-parallel
//! training workers rely on).
//!
//! The kernels know nothing about NF4/AWQ layouts: callers pass a
//! `decode(row0, rows, panel)` closure (see `quant::QuantWeight`).

use anyhow::{ensure, Result};

use super::{parallel_over_rows, Tensor};

/// Decoded rows per K-block (mirrors `matmul_panel`'s KC). The scratch
/// panel holds `KC * dout` f32 — a few MB at most, independent of din.
const KC: usize = 256;

/// `y = x @ W` for a packed `(din, dout)` weight, decoding W's rows
/// [r0, r0 + rows) on demand via `decode(r0, rows, panel)` (row-major
/// `rows x dout` into `panel`).
pub fn fused_matmul<F>(x: &Tensor, din: usize, dout: usize, mut decode: F) -> Result<Tensor>
where
    F: FnMut(usize, usize, &mut [f32]),
{
    ensure!(
        x.rank() == 2 && x.shape[1] == din,
        "fused matmul shape mismatch: {:?} @ packed ({din}, {dout})",
        x.shape
    );
    let m = x.shape[0];
    let mut out = vec![0.0f32; m * dout];
    if m == 0 || din == 0 || dout == 0 {
        return Ok(Tensor::from_vec(&[m, dout], out));
    }
    // One dispatch decision per call (caller thread), captured by the
    // row workers — a fused matmul never mixes kernels.
    let fast = crate::tensor::simd_kernels_active();
    let mut panel = vec![0.0f32; KC.min(din) * dout];
    let mut p0 = 0;
    while p0 < din {
        let pend = (p0 + KC).min(din);
        let rows = pend - p0;
        decode(p0, rows, &mut panel[..rows * dout]);
        let decoded: &[f32] = &panel[..rows * dout];
        parallel_over_rows(&mut out, m, dout, |i, orow| {
            let xrow = &x.data[i * din..(i + 1) * din];
            if fast {
                super::simd::fma_row_block(orow, &xrow[p0..pend], decoded, dout);
            } else {
                for p in p0..pend {
                    let av = xrow[p];
                    let wrow = &decoded[(p - p0) * dout..(p - p0 + 1) * dout];
                    for (o, &bv) in orow.iter_mut().zip(wrow) {
                        *o += av * bv;
                    }
                }
            }
        });
        p0 = pend;
    }
    Ok(Tensor::from_vec(&[m, dout], out))
}

/// `y = g @ W^T` for a packed `(din, dout)` weight: `g` is `(m, dout)`,
/// the result `(m, din)` — the backward's `dL/dx` against a frozen
/// quantized base, without materializing W or W^T.
pub fn fused_matmul_t<F>(g: &Tensor, din: usize, dout: usize, mut decode: F) -> Result<Tensor>
where
    F: FnMut(usize, usize, &mut [f32]),
{
    ensure!(
        g.rank() == 2 && g.shape[1] == dout,
        "fused transposed matmul shape mismatch: {:?} @ packed ({din}, {dout})^T",
        g.shape
    );
    let m = g.shape[0];
    let mut out = vec![0.0f32; m * din];
    if m == 0 || din == 0 || dout == 0 {
        return Ok(Tensor::from_vec(&[m, din], out));
    }
    let fast = crate::tensor::simd_kernels_active();
    let mut panel = vec![0.0f32; KC.min(din) * dout];
    let mut tpanel = if fast {
        vec![0.0f32; KC.min(din) * dout]
    } else {
        Vec::new()
    };
    let mut p0 = 0;
    while p0 < din {
        let pend = (p0 + KC).min(din);
        let rows = pend - p0;
        decode(p0, rows, &mut panel[..rows * dout]);
        let decoded: &[f32] = &panel[..rows * dout];
        if fast {
            // Transpose the decoded panel once (amortized over all m
            // rows) so the contraction runs through the same
            // `fma_row_block` microkernel as dense `g @ W^T` — keeping
            // the two bit-identical under SIMD as well.
            for r in 0..rows {
                for j in 0..dout {
                    tpanel[j * rows + r] = decoded[r * dout + j];
                }
            }
        }
        let transposed: &[f32] = &tpanel[..if fast { rows * dout } else { 0 }];
        parallel_over_rows(&mut out, m, din, |i, orow| {
            let grow = &g.data[i * dout..(i + 1) * dout];
            if fast {
                // out starts zeroed and each p lives in exactly one
                // K-block, so accumulate-into-zero equals assignment.
                super::simd::fma_row_block(&mut orow[p0..pend], grow, transposed, rows);
            } else {
                for p in p0..pend {
                    let wrow = &decoded[(p - p0) * dout..(p - p0 + 1) * dout];
                    // Same per-element order as dy.matmul(&w.transpose2()):
                    // ascending contraction index, single accumulator.
                    let mut acc = 0.0f32;
                    for (&gv, &wv) in grow.iter().zip(wrow) {
                        acc += gv * wv;
                    }
                    orow[p] = acc;
                }
            }
        });
        p0 = pend;
    }
    Ok(Tensor::from_vec(&[m, din], out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// "Decoder" that serves rows of an already-dense matrix — isolates
    /// the kernel's blocking/accumulation from any quantization format.
    fn dense_rows(w: &Tensor) -> impl FnMut(usize, usize, &mut [f32]) + '_ {
        let dout = w.shape[1];
        move |r0, rows, panel| {
            panel.copy_from_slice(&w.data[r0 * dout..(r0 + rows) * dout]);
        }
    }

    #[test]
    fn fused_matmul_matches_dense_bitwise() {
        let mut rng = Rng::new(40);
        for (m, din, dout) in [(1, 64, 32), (7, 300, 17), (33, 512, 64), (5, 64, 300)] {
            let x = Tensor::randn(&[m, din], 1.0, &mut rng);
            let w = Tensor::randn(&[din, dout], 0.1, &mut rng);
            let fused = fused_matmul(&x, din, dout, dense_rows(&w)).unwrap();
            let dense = x.matmul(&w).unwrap();
            assert_eq!(fused, dense, "({m},{din},{dout})");
        }
    }

    #[test]
    fn fused_matmul_t_matches_dense_bitwise() {
        let mut rng = Rng::new(41);
        for (m, din, dout) in [(1, 64, 32), (9, 300, 21), (17, 512, 48)] {
            let g = Tensor::randn(&[m, dout], 1.0, &mut rng);
            let w = Tensor::randn(&[din, dout], 0.1, &mut rng);
            let fused = fused_matmul_t(&g, din, dout, dense_rows(&w)).unwrap();
            let dense = g.matmul(&w.transpose2()).unwrap();
            assert_eq!(fused, dense, "({m},{din},{dout})");
        }
    }

    #[test]
    fn fused_is_deterministic_across_calls() {
        let mut rng = Rng::new(42);
        let (m, din, dout) = (48, 512, 96);
        let x = Tensor::randn(&[m, din], 1.0, &mut rng);
        let w = Tensor::randn(&[din, dout], 0.1, &mut rng);
        let a = fused_matmul(&x, din, dout, dense_rows(&w)).unwrap();
        let b = fused_matmul(&x, din, dout, dense_rows(&w)).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn fused_rejects_shape_mismatch() {
        let x = Tensor::zeros(&[2, 8]);
        assert!(fused_matmul(&x, 16, 4, |_, _, _| {}).is_err());
        assert!(fused_matmul_t(&x, 16, 4, |_, _, _| {}).is_err());
    }
}
