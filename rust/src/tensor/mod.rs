//! Host-side f32 tensors + dense linear algebra.
//!
//! This is the substrate for everything the coordinator does *off* the
//! accelerator: parameter initialization, quantization, host-side PEFT
//! oracles (rust/src/peft), requantization-error analysis, and checks
//! against the runtime outputs. Deliberately simple (row-major, f32).

use anyhow::{bail, Result};

use crate::util::rng::Rng;

/// A dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    pub fn ones(shape: &[usize]) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![1.0; shape.iter().product()],
        }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor {
            shape: shape.to_vec(),
            data,
        }
    }

    /// N(0, std^2) initialization.
    pub fn randn(shape: &[usize], std: f32, rng: &mut Rng) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: rng.normal_vec(shape.iter().product(), std),
        }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// 2-D accessor.
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.rank(), 2);
        self.data[i * self.shape[1] + j]
    }

    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        debug_assert_eq!(self.rank(), 2);
        let cols = self.shape[1];
        self.data[i * cols + j] = v;
    }

    /// Matrix multiply: (m, k) @ (k, n) -> (m, n).
    pub fn matmul(&self, other: &Tensor) -> Result<Tensor> {
        if self.rank() != 2 || other.rank() != 2 || self.shape[1] != other.shape[0] {
            bail!("matmul shape mismatch {:?} @ {:?}", self.shape, other.shape);
        }
        let (m, k) = (self.shape[0], self.shape[1]);
        let n = other.shape[1];
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            let arow = &self.data[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for (p, &a) in arow.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[p * n..(p + 1) * n];
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += a * b;
                }
            }
        }
        Ok(Tensor::from_vec(&[m, n], out))
    }

    pub fn transpose2(&self) -> Tensor {
        assert_eq!(self.rank(), 2);
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = self.data[i * n + j];
            }
        }
        Tensor::from_vec(&[n, m], out)
    }

    pub fn add(&self, other: &Tensor) -> Result<Tensor> {
        if self.shape != other.shape {
            bail!("add shape mismatch {:?} vs {:?}", self.shape, other.shape);
        }
        Ok(Tensor::from_vec(
            &self.shape,
            self.data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a + b)
                .collect(),
        ))
    }

    pub fn sub(&self, other: &Tensor) -> Result<Tensor> {
        if self.shape != other.shape {
            bail!("sub shape mismatch {:?} vs {:?}", self.shape, other.shape);
        }
        Ok(Tensor::from_vec(
            &self.shape,
            self.data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a - b)
                .collect(),
        ))
    }

    pub fn scale(&self, s: f32) -> Tensor {
        Tensor::from_vec(&self.shape, self.data.iter().map(|a| a * s).collect())
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Tensor {
        let mut t = Tensor::zeros(&[n, n]);
        for i in 0..n {
            t.set2(i, i, 1.0);
        }
        t
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Max |x| (the L-infinity magnitude §4's requantization bound uses).
    pub fn linf_norm(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, x| m.max(x.abs()))
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()))
    }

    /// Gauss-Jordan inverse with partial pivoting (square 2-D).
    ///
    /// The host-side *exact* Cayley baseline uses this — it is the matrix
    /// inversion the paper's CNP removes from the accelerator graph.
    pub fn inverse(&self) -> Result<Tensor> {
        if self.rank() != 2 || self.shape[0] != self.shape[1] {
            bail!("inverse needs square matrix, got {:?}", self.shape);
        }
        let n = self.shape[0];
        let mut a: Vec<f64> = self.data.iter().map(|&x| x as f64).collect();
        let mut inv: Vec<f64> = Tensor::eye(n).data.iter().map(|&x| x as f64).collect();
        for col in 0..n {
            // pivot
            let mut piv = col;
            for r in col + 1..n {
                if a[r * n + col].abs() > a[piv * n + col].abs() {
                    piv = r;
                }
            }
            if a[piv * n + col].abs() < 1e-12 {
                bail!("singular matrix");
            }
            if piv != col {
                for j in 0..n {
                    a.swap(col * n + j, piv * n + j);
                    inv.swap(col * n + j, piv * n + j);
                }
            }
            let d = a[col * n + col];
            for j in 0..n {
                a[col * n + j] /= d;
                inv[col * n + j] /= d;
            }
            for r in 0..n {
                if r == col {
                    continue;
                }
                let f = a[r * n + col];
                if f == 0.0 {
                    continue;
                }
                for j in 0..n {
                    a[r * n + j] -= f * a[col * n + j];
                    inv[r * n + j] -= f * inv[col * n + j];
                }
            }
        }
        Ok(Tensor::from_vec(
            &[n, n],
            inv.into_iter().map(|x| x as f32).collect(),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::from_vec(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_rejects_mismatch() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[2, 3]);
        assert!(a.matmul(&b).is_err());
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(0);
        let a = Tensor::randn(&[3, 5], 1.0, &mut rng);
        assert_eq!(a.transpose2().transpose2(), a);
    }

    #[test]
    fn inverse_recovers_identity() {
        let mut rng = Rng::new(1);
        // diagonally dominant => well-conditioned
        let mut a = Tensor::randn(&[8, 8], 0.1, &mut rng);
        for i in 0..8 {
            let v = a.at2(i, i);
            a.set2(i, i, v + 1.0);
        }
        let inv = a.inverse().unwrap();
        let prod = a.matmul(&inv).unwrap();
        assert!(prod.max_abs_diff(&Tensor::eye(8)) < 1e-5);
    }

    #[test]
    fn inverse_rejects_singular() {
        let a = Tensor::zeros(&[3, 3]);
        assert!(a.inverse().is_err());
    }

    #[test]
    fn norms() {
        let a = Tensor::from_vec(&[2, 2], vec![3.0, 0.0, 0.0, -4.0]);
        assert!((a.fro_norm() - 5.0).abs() < 1e-6);
        assert_eq!(a.linf_norm(), 4.0);
    }

    #[test]
    fn randn_stats() {
        let mut rng = Rng::new(5);
        let t = Tensor::randn(&[100, 100], 0.02, &mut rng);
        let mean: f32 = t.data.iter().sum::<f32>() / t.numel() as f32;
        let var: f32 =
            t.data.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / t.numel() as f32;
        assert!(mean.abs() < 1e-3);
        assert!((var.sqrt() - 0.02).abs() < 2e-3);
    }
}
