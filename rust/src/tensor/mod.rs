//! Host-side f32 tensors + dense linear algebra.
//!
//! This is the substrate for everything the coordinator does *off* the
//! accelerator: parameter initialization, quantization, host-side PEFT
//! oracles (rust/src/peft), requantization-error analysis, and checks
//! against the runtime outputs. Deliberately simple (row-major, f32).
//!
//! Hot-path inner loops optionally dispatch to the explicit-SIMD
//! microkernels in [`simd`] when the crate is built with
//! `--features simd` (see [`simd_kernels_active`]); the scalar kernels
//! in this file are the locked oracle and the only path in default
//! builds.

pub mod fused;
pub mod simd;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;

use anyhow::{bail, Result};

use crate::util::rng::Rng;

/// Process-wide kill switch for the SIMD kernel dispatch, used by the
/// equivalence tests and the roofline bench to measure the scalar
/// oracle from a `--features simd` build. Global (not thread-local) on
/// purpose: kernels run on worker threads the caller never sees, and a
/// split-brain dispatch would break the bitwise thread-count
/// invariance.
static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);

/// Force every kernel onto its scalar oracle path (`true`) or restore
/// SIMD dispatch (`false`); returns the previous setting. No-op in
/// builds without the `simd` feature. Test/bench hook — flipping it
/// concurrently with bitwise-comparison tests is a race, so such tests
/// serialize on a lock.
pub fn force_scalar_kernels(on: bool) -> bool {
    FORCE_SCALAR.swap(on, Ordering::SeqCst)
}

/// Whether kernel calls currently dispatch to the SIMD microkernels
/// ([`simd`]): requires the `simd` cargo feature and no
/// [`force_scalar_kernels`] override. Constant across threads, so a
/// kernel and its workers always agree.
pub fn simd_kernels_active() -> bool {
    cfg!(feature = "simd") && !FORCE_SCALAR.load(Ordering::SeqCst)
}

/// A dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    pub fn ones(shape: &[usize]) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![1.0; shape.iter().product()],
        }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor {
            shape: shape.to_vec(),
            data,
        }
    }

    /// N(0, std^2) initialization.
    pub fn randn(shape: &[usize], std: f32, rng: &mut Rng) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: rng.normal_vec(shape.iter().product(), std),
        }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// 2-D accessor.
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.rank(), 2);
        self.data[i * self.shape[1] + j]
    }

    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        debug_assert_eq!(self.rank(), 2);
        let cols = self.shape[1];
        self.data[i * cols + j] = v;
    }

    /// Matrix multiply: (m, k) @ (k, n) -> (m, n).
    ///
    /// Cache-blocked over the contraction dimension and multithreaded
    /// over row panels for large problems; accumulation order per
    /// output element is identical at every thread count, so results
    /// are bitwise deterministic.
    pub fn matmul(&self, other: &Tensor) -> Result<Tensor> {
        if self.rank() != 2 || other.rank() != 2 || self.shape[1] != other.shape[0] {
            bail!("matmul shape mismatch {:?} @ {:?}", self.shape, other.shape);
        }
        let (m, k) = (self.shape[0], self.shape[1]);
        let n = other.shape[1];
        let mut out = vec![0.0f32; m * n];
        if m == 0 || k == 0 || n == 0 {
            return Ok(Tensor::from_vec(&[m, n], out));
        }
        // Dispatch decided once per call, on the calling thread, and
        // shared by every worker: one matmul never mixes kernels.
        let fast = simd_kernels_active();
        let threads = matmul_threads(m, m * k * n);
        if threads <= 1 {
            matmul_panel(&self.data, &other.data, &mut out, 0, m, k, n, fast);
        } else {
            let rows_per = m.div_ceil(threads);
            std::thread::scope(|s| {
                for (ci, chunk) in out.chunks_mut(rows_per * n).enumerate() {
                    let a = &self.data;
                    let b = &other.data;
                    s.spawn(move || {
                        let rows = chunk.len() / n;
                        matmul_panel(a, b, chunk, ci * rows_per, rows, k, n, fast);
                    });
                }
            });
        }
        Ok(Tensor::from_vec(&[m, n], out))
    }

    pub fn transpose2(&self) -> Tensor {
        assert_eq!(self.rank(), 2);
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = self.data[i * n + j];
            }
        }
        Tensor::from_vec(&[n, m], out)
    }

    pub fn add(&self, other: &Tensor) -> Result<Tensor> {
        if self.shape != other.shape {
            bail!("add shape mismatch {:?} vs {:?}", self.shape, other.shape);
        }
        Ok(Tensor::from_vec(
            &self.shape,
            self.data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a + b)
                .collect(),
        ))
    }

    pub fn sub(&self, other: &Tensor) -> Result<Tensor> {
        if self.shape != other.shape {
            bail!("sub shape mismatch {:?} vs {:?}", self.shape, other.shape);
        }
        Ok(Tensor::from_vec(
            &self.shape,
            self.data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a - b)
                .collect(),
        ))
    }

    pub fn scale(&self, s: f32) -> Tensor {
        Tensor::from_vec(&self.shape, self.data.iter().map(|a| a * s).collect())
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Tensor {
        let mut t = Tensor::zeros(&[n, n]);
        for i in 0..n {
            t.set2(i, i, 1.0);
        }
        t
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Max |x| (the L-infinity magnitude §4's requantization bound uses).
    pub fn linf_norm(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, x| m.max(x.abs()))
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()))
    }

    /// Gauss-Jordan inverse with partial pivoting (square 2-D).
    ///
    /// The host-side *exact* Cayley baseline uses this — it is the matrix
    /// inversion the paper's CNP removes from the accelerator graph.
    pub fn inverse(&self) -> Result<Tensor> {
        if self.rank() != 2 || self.shape[0] != self.shape[1] {
            bail!("inverse needs square matrix, got {:?}", self.shape);
        }
        let n = self.shape[0];
        let mut a: Vec<f64> = self.data.iter().map(|&x| x as f64).collect();
        let mut inv: Vec<f64> = Tensor::eye(n).data.iter().map(|&x| x as f64).collect();
        for col in 0..n {
            // pivot
            let mut piv = col;
            for r in col + 1..n {
                if a[r * n + col].abs() > a[piv * n + col].abs() {
                    piv = r;
                }
            }
            if a[piv * n + col].abs() < 1e-12 {
                bail!("singular matrix");
            }
            if piv != col {
                for j in 0..n {
                    a.swap(col * n + j, piv * n + j);
                    inv.swap(col * n + j, piv * n + j);
                }
            }
            let d = a[col * n + col];
            for j in 0..n {
                a[col * n + j] /= d;
                inv[col * n + j] /= d;
            }
            for r in 0..n {
                if r == col {
                    continue;
                }
                let f = a[r * n + col];
                if f == 0.0 {
                    continue;
                }
                for j in 0..n {
                    a[r * n + j] -= f * a[col * n + j];
                    inv[r * n + j] -= f * inv[col * n + j];
                }
            }
        }
        Ok(Tensor::from_vec(
            &[n, n],
            inv.into_iter().map(|x| x as f32).collect(),
        ))
    }
}

/// One thread's share of a matmul: rows [row0, row0+rows) of the
/// output, k-blocked so a panel of B stays cache-hot across rows.
/// `fast` routes to the SIMD microkernel (same KC blocking, same
/// per-element ascending-contraction accumulation).
fn matmul_panel(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    row0: usize,
    rows: usize,
    k: usize,
    n: usize,
    fast: bool,
) {
    if fast {
        simd::matmul_panel(a, b, out, row0, rows, k, n);
        return;
    }
    const KC: usize = 256;
    let mut p0 = 0;
    while p0 < k {
        let pend = (p0 + KC).min(k);
        for i in 0..rows {
            let arow = &a[(row0 + i) * k..(row0 + i) * k + k];
            let orow = &mut out[i * n..(i + 1) * n];
            for p in p0..pend {
                // No zero-skip: the old `if av == 0.0 { continue }` fast
                // path made latency depend on input sparsity (timing
                // noise in every bench) and blocked vectorization of
                // this loop. Dropping it only changes results on
                // non-finite inputs (0 * inf), which no caller feeds.
                let av = arow[p];
                let brow = &b[p * n..p * n + n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
        p0 = pend;
    }
}

thread_local! {
    /// Per-thread cap on kernel-internal row threading. Data-parallel
    /// training workers set this to 1 so the coarse per-microbatch
    /// parallelism is not oversubscribed by nested per-matmul threads.
    /// Results are unaffected: every output row is computed by exactly
    /// one thread whatever the count.
    static THREAD_CAP: std::cell::Cell<usize> = const { std::cell::Cell::new(usize::MAX) };
}

/// Cap kernel-internal threading for the *calling* thread (and any
/// kernel invoked from it). `usize::MAX` restores the default.
///
/// Public so the SIMD equivalence suite (rust/tests/simd_kernels.rs)
/// and benches can sweep thread caps; results are identical at every
/// cap (each output row is computed by exactly one thread).
pub fn set_thread_cap(cap: usize) {
    THREAD_CAP.with(|c| c.set(cap.max(1)));
}

/// Hardware parallelism, resolved once per process: the old
/// per-matmul `available_parallelism()` syscall showed up in gemv-heavy
/// decode profiles.
fn hw_parallelism() -> usize {
    static HW: OnceLock<usize> = OnceLock::new();
    *HW.get_or_init(|| {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    })
}

/// Worker count for a matmul of `flops` fused multiply-adds over `rows`
/// output rows (1 below the threading threshold). `THREAD_CAP`
/// semantics are unchanged: the per-thread cap still applies on every
/// call — only the hardware count is cached.
fn matmul_threads(rows: usize, flops: usize) -> usize {
    if flops < (1 << 18) {
        return 1;
    }
    let cap = THREAD_CAP.with(|c| c.get());
    hw_parallelism().min(cap).min(rows).max(1)
}

/// Apply `f(row_index, row_slice)` over the rows of a (rows, cols)
/// buffer, in parallel for large outputs. Each row is written by
/// exactly one thread, so the result is deterministic.
pub(crate) fn parallel_over_rows<F>(out: &mut [f32], rows: usize, cols: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    assert_eq!(out.len(), rows * cols);
    if rows == 0 || cols == 0 {
        return;
    }
    let threads = matmul_threads(rows, rows * cols * 16);
    if threads <= 1 {
        for (i, row) in out.chunks_mut(cols).enumerate() {
            f(i, row);
        }
        return;
    }
    let rows_per = rows.div_ceil(threads);
    std::thread::scope(|s| {
        for (ci, chunk) in out.chunks_mut(rows_per * cols).enumerate() {
            let f = &f;
            s.spawn(move || {
                for (ri, row) in chunk.chunks_mut(cols).enumerate() {
                    f(ci * rows_per + ri, row);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The textbook triple loop, for parity checks against the blocked
    /// threaded implementation.
    fn matmul_naive(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = (a.shape[0], a.shape[1]);
        let n = b.shape[1];
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0f32;
                for p in 0..k {
                    acc += a.data[i * k + p] * b.data[p * n + j];
                }
                out[i * n + j] = acc;
            }
        }
        Tensor::from_vec(&[m, n], out)
    }

    #[test]
    fn blocked_matmul_matches_naive() {
        let mut rng = Rng::new(21);
        for (m, k, n) in [(3, 5, 7), (17, 64, 9), (33, 300, 21)] {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let got = a.matmul(&b).unwrap();
            let want = matmul_naive(&a, &b);
            assert!(got.max_abs_diff(&want) < 1e-4, "({m},{k},{n})");
        }
    }

    #[test]
    fn threaded_matmul_matches_naive_above_threshold() {
        // 128*128*128 = 2M MACs: well above the threading threshold.
        let mut rng = Rng::new(22);
        let a = Tensor::randn(&[128, 128], 1.0, &mut rng);
        let b = Tensor::randn(&[128, 128], 1.0, &mut rng);
        let got = a.matmul(&b).unwrap();
        let want = matmul_naive(&a, &b);
        assert!(got.max_abs_diff(&want) < 1e-3);
    }

    #[test]
    fn matmul_is_deterministic() {
        let mut rng = Rng::new(23);
        let a = Tensor::randn(&[96, 200], 1.0, &mut rng);
        let b = Tensor::randn(&[200, 64], 1.0, &mut rng);
        let x = a.matmul(&b).unwrap();
        let y = a.matmul(&b).unwrap();
        assert_eq!(x, y, "repeated matmuls must agree bitwise");
    }

    #[test]
    fn matmul_threads_respects_caps_and_cached_parallelism() {
        // Below the flops threshold: always single-threaded.
        assert_eq!(matmul_threads(64, 1 << 10), 1);
        // The OnceLock'd hardware count is stable and matches the OS.
        let hw = hw_parallelism();
        assert!(hw >= 1);
        assert_eq!(hw, hw_parallelism());
        assert_eq!(
            hw,
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        );
        // THREAD_CAP semantics unchanged: the per-thread cap still
        // applies per call, on top of the cached hardware count.
        set_thread_cap(2);
        assert!(matmul_threads(64, 1 << 20) <= 2);
        assert_eq!(matmul_threads(1, 1 << 20), 1);
        set_thread_cap(usize::MAX);
        assert_eq!(matmul_threads(1 << 20, 1 << 20), hw);
    }

    #[test]
    fn parallel_over_rows_covers_every_row() {
        let (rows, cols) = (301, 40);
        let mut out = vec![0f32; rows * cols];
        parallel_over_rows(&mut out, rows, cols, |i, row| {
            for (j, v) in row.iter_mut().enumerate() {
                *v = (i * cols + j) as f32;
            }
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as f32);
        }
    }

    #[test]
    fn matmul_known() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::from_vec(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_rejects_mismatch() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[2, 3]);
        assert!(a.matmul(&b).is_err());
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(0);
        let a = Tensor::randn(&[3, 5], 1.0, &mut rng);
        assert_eq!(a.transpose2().transpose2(), a);
    }

    #[test]
    fn inverse_recovers_identity() {
        let mut rng = Rng::new(1);
        // diagonally dominant => well-conditioned
        let mut a = Tensor::randn(&[8, 8], 0.1, &mut rng);
        for i in 0..8 {
            let v = a.at2(i, i);
            a.set2(i, i, v + 1.0);
        }
        let inv = a.inverse().unwrap();
        let prod = a.matmul(&inv).unwrap();
        assert!(prod.max_abs_diff(&Tensor::eye(8)) < 1e-5);
    }

    #[test]
    fn inverse_rejects_singular() {
        let a = Tensor::zeros(&[3, 3]);
        assert!(a.inverse().is_err());
    }

    #[test]
    fn norms() {
        let a = Tensor::from_vec(&[2, 2], vec![3.0, 0.0, 0.0, -4.0]);
        assert!((a.fro_norm() - 5.0).abs() < 1e-6);
        assert_eq!(a.linf_norm(), 4.0);
    }

    #[test]
    fn randn_stats() {
        let mut rng = Rng::new(5);
        let t = Tensor::randn(&[100, 100], 0.02, &mut rng);
        let mean: f32 = t.data.iter().sum::<f32>() / t.numel() as f32;
        let var: f32 =
            t.data.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / t.numel() as f32;
        assert!(mean.abs() < 1e-3);
        assert!((var.sqrt() - 0.02).abs() < 2e-3);
    }
}
