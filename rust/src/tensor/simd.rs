//! Explicit-SIMD inner microkernels for the f32 hot paths.
//!
//! Every kernel here has a scalar twin that stays the *locked oracle*
//! (the same pattern `dequantize()` plays for `tensor::fused`): the
//! SIMD path is only ever reached through a dispatch check
//! ([`super::simd_kernels_active`]), which is false unless the crate is
//! built with `--features simd`. The kernels themselves are compiled
//! unconditionally — they are plain stable Rust — so the equivalence
//! tests exercise them in every build.
//!
//! # Dispatch
//!
//! On x86_64 a one-time, cached CPU probe selects an AVX2+FMA
//! instantiation (`#[target_feature]` wrappers around `#[inline(always)]`
//! lane kernels, so `f32::mul_add` compiles to `vfmadd` — never a libm
//! call). Everywhere else a portable lane-blocked fallback runs, using
//! plain `a * b + c` — which makes the fallback bitwise identical to
//! the scalar oracle for the accumulate-style kernels. The probe result
//! is process-constant, so results are deterministic within a build at
//! every thread count and `set_thread_cap` value: the dispatch decision
//! never varies call-to-call.
//!
//! # Equivalence contract (per kernel)
//!
//! * [`fma_row_block`] / [`matmul_panel`]: per output element the
//!   contraction runs in ascending index order with a single
//!   accumulator — exactly the scalar chain, but with fused
//!   multiply-adds. Kernels that share this microkernel (dense matmul,
//!   `fused_matmul`, `fused_matmul_t`) therefore stay *bitwise
//!   consistent with each other* within a build, and match the scalar
//!   oracle to <= 1e-5 (the only difference is the intermediate
//!   rounding an FMA removes).
//! * [`dot`]: fixed 4x8-lane partial sums reduced in a fixed order —
//!   deterministic, <= 1e-5 relative to the scalar left-to-right sum.

use std::sync::OnceLock;

/// f32 lanes per vector register (AVX2 ymm).
const LANES: usize = 8;

/// Register tile width of the row microkernel (4 ymm accumulators).
const TILE: usize = 4 * LANES;

/// Cached runtime probe for AVX2 + FMA.
#[cfg(target_arch = "x86_64")]
fn have_avx2_fma() -> bool {
    static PROBE: OnceLock<bool> = OnceLock::new();
    *PROBE.get_or_init(|| {
        std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
    })
}

#[cfg(not(target_arch = "x86_64"))]
fn have_avx2_fma() -> bool {
    false
}

/// One fused (or plain) multiply-add, selected at monomorphization
/// time. The `FMA` instantiation is only ever inlined into
/// `#[target_feature(enable = "fma")]` wrappers, where `mul_add`
/// lowers to a `vfmadd` instruction rather than a libm call.
#[inline(always)]
fn fma1<const FMA: bool>(a: f32, b: f32, c: f32) -> f32 {
    if FMA {
        a.mul_add(b, c)
    } else {
        a * b + c
    }
}

// ---------------------------------------------------------------------------
// Row microkernel: out[j] += sum_p x[p] * w[p * n + j]
// ---------------------------------------------------------------------------

#[inline(always)]
fn fma_row_block_inner<const FMA: bool>(out: &mut [f32], x: &[f32], w: &[f32], n: usize) {
    debug_assert_eq!(out.len(), n);
    debug_assert_eq!(w.len(), x.len() * n);
    let kc = x.len();
    let mut j0 = 0;
    // 4 accumulator registers of LANES each stay live across the whole
    // contraction — the memory round-trip per p of the scalar kernel
    // becomes one load/store per TILE columns.
    while j0 + TILE <= n {
        let mut acc = [[0.0f32; LANES]; 4];
        for (t, a) in acc.iter_mut().enumerate() {
            a.copy_from_slice(&out[j0 + t * LANES..j0 + (t + 1) * LANES]);
        }
        for p in 0..kc {
            let av = x[p];
            let wrow = &w[p * n + j0..p * n + j0 + TILE];
            for (t, a) in acc.iter_mut().enumerate() {
                for l in 0..LANES {
                    a[l] = fma1::<FMA>(av, wrow[t * LANES + l], a[l]);
                }
            }
        }
        for (t, a) in acc.iter().enumerate() {
            out[j0 + t * LANES..j0 + (t + 1) * LANES].copy_from_slice(a);
        }
        j0 += TILE;
    }
    while j0 + LANES <= n {
        let mut acc = [0.0f32; LANES];
        acc.copy_from_slice(&out[j0..j0 + LANES]);
        for p in 0..kc {
            let av = x[p];
            let wrow = &w[p * n + j0..p * n + j0 + LANES];
            for l in 0..LANES {
                acc[l] = fma1::<FMA>(av, wrow[l], acc[l]);
            }
        }
        out[j0..j0 + LANES].copy_from_slice(&acc);
        j0 += LANES;
    }
    // Scalar tail: same single-accumulator ascending-p chain.
    for j in j0..n {
        let mut acc = out[j];
        for p in 0..kc {
            acc = fma1::<FMA>(x[p], w[p * n + j], acc);
        }
        out[j] = acc;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn fma_row_block_avx2(out: &mut [f32], x: &[f32], w: &[f32], n: usize) {
    fma_row_block_inner::<true>(out, x, w, n);
}

/// `out[j] += sum_p x[p] * w[p * n + j]` — the shared microkernel
/// behind dense matmul, the fused quant matmuls, and the CNP block
/// rotations. Per output element the contraction is a single
/// accumulator chain in ascending `p`, so every caller of this kernel
/// is bitwise consistent with every other within a build.
pub fn fma_row_block(out: &mut [f32], x: &[f32], w: &[f32], n: usize) {
    assert_eq!(out.len(), n);
    assert_eq!(w.len(), x.len() * n);
    #[cfg(target_arch = "x86_64")]
    {
        if have_avx2_fma() {
            // SAFETY: AVX2 + FMA presence verified by the runtime probe.
            unsafe { fma_row_block_avx2(out, x, w, n) };
            return;
        }
    }
    fma_row_block_inner::<false>(out, x, w, n);
}

/// The dense matmul panel in SIMD form: same `KC` contraction blocking
/// as the scalar `matmul_panel`, rows of the output via
/// [`fma_row_block`].
pub(crate) fn matmul_panel(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    row0: usize,
    rows: usize,
    k: usize,
    n: usize,
) {
    const KC: usize = 256;
    let mut p0 = 0;
    while p0 < k {
        let pend = (p0 + KC).min(k);
        let bpanel = &b[p0 * n..pend * n];
        for i in 0..rows {
            let arow = &a[(row0 + i) * k + p0..(row0 + i) * k + pend];
            fma_row_block(&mut out[i * n..(i + 1) * n], arow, bpanel, n);
        }
        p0 = pend;
    }
}

// ---------------------------------------------------------------------------
// Dot product (the HOFT reflection hot path)
// ---------------------------------------------------------------------------

#[inline(always)]
fn dot_inner<const FMA: bool>(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let mut acc = [[0.0f32; LANES]; 4];
    let mut i = 0;
    while i + TILE <= n {
        for (t, ac) in acc.iter_mut().enumerate() {
            let sa = &a[i + t * LANES..i + (t + 1) * LANES];
            let sb = &b[i + t * LANES..i + (t + 1) * LANES];
            for l in 0..LANES {
                ac[l] = fma1::<FMA>(sa[l], sb[l], ac[l]);
            }
        }
        i += TILE;
    }
    while i + LANES <= n {
        for l in 0..LANES {
            acc[0][l] = fma1::<FMA>(a[i + l], b[i + l], acc[0][l]);
        }
        i += LANES;
    }
    // Fixed reduction order: pairwise over the 4 registers, then left
    // to right across lanes, then the scalar tail. Deterministic.
    let mut lanes = [0.0f32; LANES];
    for l in 0..LANES {
        lanes[l] = (acc[0][l] + acc[1][l]) + (acc[2][l] + acc[3][l]);
    }
    let mut s = 0.0f32;
    for v in lanes {
        s += v;
    }
    for j in i..n {
        s = fma1::<FMA>(a[j], b[j], s);
    }
    s
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
    dot_inner::<true>(a, b)
}

/// Lane-parallel dot product with a fixed reduction order.
/// Deterministic; <= 1e-5 relative to the scalar left-to-right sum
/// (lane partial sums reassociate the accumulation).
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    {
        if have_avx2_fma() {
            // SAFETY: AVX2 + FMA presence verified by the runtime probe.
            return unsafe { dot_avx2(a, b) };
        }
    }
    dot_inner::<false>(a, b)
}

// ---------------------------------------------------------------------------
// Arithmetic-peak probe (the roofline bench's denominator)
// ---------------------------------------------------------------------------

#[inline(always)]
fn peak_inner<const FMA: bool>(iters: usize) -> f32 {
    // 8 independent LANES-wide accumulator chains: enough to cover FMA
    // latency x throughput on every recent x86 core, so the loop runs
    // at the per-core multiply-add peak of whichever instruction set
    // this instantiation targets.
    let m = std::hint::black_box(0.999_999f32);
    let c = std::hint::black_box(1.0e-9f32);
    let mut acc = [[0.0f32; LANES]; 8];
    for (t, row) in acc.iter_mut().enumerate() {
        for (l, v) in row.iter_mut().enumerate() {
            *v = (t * LANES + l) as f32 * 1.0e-3;
        }
    }
    for _ in 0..iters {
        for row in acc.iter_mut() {
            for v in row.iter_mut() {
                *v = fma1::<FMA>(*v, m, c);
            }
        }
    }
    let mut s = 0.0f32;
    for row in &acc {
        for v in row {
            s += *v;
        }
    }
    s
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn peak_avx2(iters: usize) -> f32 {
    peak_inner::<true>(iters)
}

/// Measured per-core arithmetic peak estimate in GFLOP/s: times a
/// register-resident multiply-add loop (no memory traffic) on the same
/// instruction set the kernels dispatch to. The roofline bench divides
/// kernel GFLOP/s by this to report a fraction of peak.
pub fn arithmetic_peak_gflops() -> f64 {
    let iters = 2_000_000usize;
    let flops = (iters * 8 * LANES * 2) as f64;
    let run = || -> f32 {
        #[cfg(target_arch = "x86_64")]
        {
            if have_avx2_fma() {
                // SAFETY: AVX2 + FMA presence verified by the probe.
                return std::hint::black_box(unsafe { peak_avx2(iters) });
            }
        }
        std::hint::black_box(peak_inner::<false>(iters))
    };
    let _ = run(); // warmup
    let mut best = f64::INFINITY;
    for _ in 0..3 {
        let t = std::time::Instant::now();
        let _ = run();
        best = best.min(t.elapsed().as_secs_f64());
    }
    flops / best / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scalar_row_block(out: &mut [f32], x: &[f32], w: &[f32], n: usize) {
        for (p, &av) in x.iter().enumerate() {
            for j in 0..n {
                out[j] += av * w[p * n + j];
            }
        }
    }

    #[test]
    fn row_block_matches_scalar_on_odd_widths() {
        // Sweep widths around the lane/tile boundaries, including n < 8.
        let mut state = 1234567u64;
        let mut next = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
            ((state >> 33) as f32 / (1u64 << 31) as f32) - 0.5
        };
        for &n in &[1usize, 5, 7, 8, 9, 24, 31, 32, 33, 40, 65, 100] {
            for &kc in &[1usize, 3, 17, 64] {
                let x: Vec<f32> = (0..kc).map(|_| next()).collect();
                let w: Vec<f32> = (0..kc * n).map(|_| next()).collect();
                let mut got = vec![0.25f32; n];
                let mut want = got.clone();
                fma_row_block(&mut got, &x, &w, n);
                scalar_row_block(&mut want, &x, &w, n);
                for j in 0..n {
                    let d = (got[j] - want[j]).abs();
                    assert!(d <= 1e-5, "n={n} kc={kc} j={j}: {} vs {}", got[j], want[j]);
                }
            }
        }
    }

    #[test]
    fn dot_matches_scalar_within_tolerance() {
        for &n in &[0usize, 1, 7, 8, 33, 100, 1000] {
            let a: Vec<f32> = (0..n).map(|i| ((i * 37 % 19) as f32 - 9.0) * 0.1).collect();
            let b: Vec<f32> = (0..n).map(|i| ((i * 53 % 23) as f32 - 11.0) * 0.1).collect();
            let got = dot(&a, &b);
            let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!(
                (got - want).abs() <= 1e-5 * (1.0 + want.abs()),
                "n={n}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn dot_is_deterministic() {
        let a: Vec<f32> = (0..513).map(|i| (i as f32).sin()).collect();
        let b: Vec<f32> = (0..513).map(|i| (i as f32).cos()).collect();
        let x = dot(&a, &b);
        let y = dot(&a, &b);
        assert_eq!(x.to_bits(), y.to_bits());
    }

    #[test]
    fn peak_probe_is_positive() {
        // Sanity only — the roofline bench does the real measurement.
        let g = arithmetic_peak_gflops();
        assert!(g.is_finite() && g > 0.0, "{g}");
    }
}
