//! Mini property-testing kit (offline substitute for `proptest`).
//!
//! Runs a property over many generated cases; on failure it re-reports
//! the failing seed so the case is reproducible, and performs a simple
//! numeric shrink (halving integer parameters) to find a smaller
//! counterexample.
//!
//! ```ignore
//! testkit::check("rotate preserves norm", 200, |g| {
//!     let b = g.choose(&[2usize, 4, 8]);
//!     ...
//!     Ok(())
//! });
//! ```

use crate::util::rng::Rng;

/// A per-case generator handle.
pub struct Gen {
    pub rng: Rng,
    pub case: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo, hi)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.next_f32() * (hi - lo)
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    pub fn vec_f32(&mut self, n: usize, std: f32) -> Vec<f32> {
        self.rng.normal_vec(n, std)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }
}

/// Run `prop` over `cases` generated cases; panic with the failing seed
/// on the first error. Seed base can be pinned with `OFT_TEST_SEED`.
pub fn check<F>(name: &str, cases: usize, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    let base = std::env::var("OFT_TEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xD1CEu64);
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64);
        let mut g = Gen {
            rng: Rng::new(seed),
            case,
        };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed}, rerun with OFT_TEST_SEED={seed}): {msg}"
            );
        }
    }
}

/// Assert |a-b| <= atol + rtol*|b| elementwise.
pub fn assert_allclose(a: &[f32], b: &[f32], atol: f32, rtol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs();
        if (x - y).abs() > tol || !x.is_finite() {
            return Err(format!(
                "element {i}: {x} vs {y} (|diff| {} > tol {tol})",
                (x - y).abs()
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let mut count = 0usize;
        let counter = std::cell::Cell::new(0usize);
        check("counts", 25, |_| {
            counter.set(counter.get() + 1);
            Ok(())
        });
        count += counter.get();
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn check_reports_failure() {
        check("fails", 10, |g| {
            if g.case == 7 {
                Err("boom".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn allclose_tolerances() {
        assert!(assert_allclose(&[1.0], &[1.0 + 1e-7], 1e-6, 0.0).is_ok());
        assert!(assert_allclose(&[100.0], &[100.1], 0.0, 1e-2).is_ok());
        assert!(assert_allclose(&[1.0], &[2.0], 1e-3, 1e-3).is_err());
        assert!(assert_allclose(&[f32::NAN], &[0.0], 1.0, 1.0).is_err());
    }
}
