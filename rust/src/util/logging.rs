//! Leveled stderr logging (offline substitute for `env_logger`).
//!
//! Controlled by `OFT_LOG` = error|warn|info|debug (default info).

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);

fn level() -> u8 {
    let v = LEVEL.load(Ordering::Relaxed);
    if v != u8::MAX {
        return v;
    }
    let parsed = match std::env::var("OFT_LOG").as_deref() {
        Ok("error") => 0,
        Ok("warn") => 1,
        Ok("debug") => 3,
        _ => 2,
    };
    LEVEL.store(parsed, Ordering::Relaxed);
    parsed
}

/// Override the log level programmatically (tests, CLI flags).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= level()
}

pub fn log(l: Level, args: std::fmt::Arguments<'_>) {
    if enabled(l) {
        let tag = match l {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        eprintln!("[{tag}] {args}");
    }
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
