//! Small shared utilities: PRNG, statistics, timing, logging, formatting.
//!
//! The offline crate registry has no `rand`/`criterion`/`log` backends, so
//! these are in-repo substrates (see DESIGN.md §Substitutions).

pub mod logging;
pub mod rng;
pub mod stats;
pub mod timer;

/// Format a byte count as a human-readable string (GiB/MiB/KiB).
pub fn human_bytes(bytes: u64) -> String {
    const K: f64 = 1024.0;
    let b = bytes as f64;
    if b >= K * K * K {
        format!("{:.2} GiB", b / (K * K * K))
    } else if b >= K * K {
        format!("{:.2} MiB", b / (K * K))
    } else if b >= K {
        format!("{:.2} KiB", b / K)
    } else {
        format!("{bytes} B")
    }
}

/// Format a parameter count (e.g. `17.65M`, `7.89M`, `1.2B`).
pub fn human_count(n: u64) -> String {
    let f = n as f64;
    if f >= 1e9 {
        format!("{:.2}B", f / 1e9)
    } else if f >= 1e6 {
        format!("{:.2}M", f / 1e6)
    } else if f >= 1e3 {
        format!("{:.1}K", f / 1e3)
    } else {
        format!("{n}")
    }
}

/// Format seconds as `HH:MM:SS` (the paper's clock-time tables).
pub fn human_clock(secs: f64) -> String {
    let s = secs.max(0.0).round() as u64;
    format!("{:02}:{:02}:{:02}", s / 3600, (s % 3600) / 60, s % 60)
}

/// Index of the largest element; ties resolve to the first (the greedy
/// decode rule — every decode path must share it or emitted tokens
/// silently diverge between paths).
///
/// NaN policy: a NaN is never the argmax. The naive `>` scan is
/// NaN-poisoned — a NaN at index 0 makes every comparison false, so a
/// single bad logit would silently decode token 0 forever in
/// `serve::tick` and `decode_greedy`. NaN entries are skipped
/// explicitly; an all-NaN or empty slice returns 0 (the caller sees a
/// deterministic token instead of a panic mid-serve).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best: Option<usize> = None;
    for (i, &x) in xs.iter().enumerate() {
        if x.is_nan() {
            continue;
        }
        match best {
            Some(b) if xs[b] >= x => {}
            _ => best = Some(i),
        }
    }
    best.unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
        assert_eq!(human_bytes(80 * 1024 * 1024 * 1024), "80.00 GiB");
    }

    #[test]
    fn argmax_basics() {
        assert_eq!(argmax(&[0.1, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
        // ties resolve to the first
        assert_eq!(argmax(&[1.0, 1.0]), 0);
    }

    #[test]
    fn argmax_skips_nan() {
        // NaN first: must not poison the scan into returning index 0.
        assert_eq!(argmax(&[f32::NAN, 3.0, 2.0]), 1);
        // NaN mid-slice: the surrounding finite values still compete.
        assert_eq!(argmax(&[1.0, f32::NAN, 2.0]), 2);
        assert_eq!(argmax(&[4.0, f32::NAN, 2.0]), 0);
        // NaN never wins, even against -inf.
        assert_eq!(argmax(&[f32::NAN, f32::NEG_INFINITY]), 1);
        // Degenerate inputs return 0 instead of panicking.
        assert_eq!(argmax(&[]), 0);
        assert_eq!(argmax(&[f32::NAN, f32::NAN]), 0);
        // Ties still resolve to the first across a NaN gap.
        assert_eq!(argmax(&[2.0, f32::NAN, 2.0]), 0);
    }

    #[test]
    fn count_formatting() {
        assert_eq!(human_count(17_650_000), "17.65M");
        assert_eq!(human_count(999), "999");
        assert_eq!(human_count(7_890_000), "7.89M");
        assert_eq!(human_count(1_200_000_000), "1.20B");
    }

    #[test]
    fn clock_formatting() {
        assert_eq!(human_clock(0.0), "00:00:00");
        assert_eq!(human_clock(3.0 * 3600.0 + 25.0 * 60.0), "03:25:00");
        assert_eq!(human_clock(12.0 * 3600.0 + 51.0 * 60.0 + 45.0), "12:51:45");
    }
}
