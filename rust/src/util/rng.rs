//! PCG64-style PRNG + distributions (offline substitute for `rand`).
//!
//! Deterministic, seedable, and good enough statistically for parameter
//! initialization and synthetic data generation. PCG XSL-RR 128/64
//! (O'Neill 2014).

/// A PCG XSL-RR 128/64 generator.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Rng {
    /// Create a generator from a seed (stream fixed).
    pub fn new(seed: u64) -> Self {
        let mut rng = Rng {
            state: 0,
            inc: (0xda3e_39cb_94b9_5bdb_u128 << 1) | 1,
        };
        rng.state = rng.inc.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    /// Derive an independent child generator (for per-worker streams).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self
            .state
            .wrapping_mul(PCG_MULT)
            .wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // Lemire's multiply-shift rejection-free approximation is fine here.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos();
            }
        }
    }

    /// Vector of N(0, std^2) f32 samples.
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32 * std).collect()
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut t = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Zipf-distributed rank in [0, n) with exponent s (vocabulary law).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // Inverse-CDF on precomputation-free approximation via rejection
        // would be slow; use simple cumulative search for small n,
        // otherwise the approximate inverse-power transform.
        if n <= 1 {
            return 0;
        }
        let u = self.next_f64().max(1e-12);
        if s == 1.0 {
            let hn = (n as f64).ln() + 0.5772;
            let x = (u * hn).exp();
            // x lies in [1, n]; rank 0 corresponds to x in [1, 2)
            ((x - 1.0) as usize).min(n - 1)
        } else {
            let p = 1.0 - s;
            let x = ((u * ((n as f64).powf(p) - 1.0) + 1.0).powf(1.0 / p)) - 1.0;
            (x as usize).min(n - 1)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(11);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn zipf_is_skewed() {
        let mut r = Rng::new(13);
        let mut counts = vec![0u32; 100];
        for _ in 0..50_000 {
            counts[r.zipf(100, 1.0)] += 1;
        }
        assert!(counts[0] > counts[10] && counts[10] > counts[50]);
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(15);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(1);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
