//! Summary statistics over measurement samples (bench-harness backbone).
//!
//! NaN policy: samples are ordered with [`f64::total_cmp`], so a NaN
//! sample can never panic the sort (the old `partial_cmp().unwrap()`
//! crashed the whole bench sweep on one bad timer read). Under total
//! order a positive NaN sorts *after* `+inf`, so NaNs surface loudly
//! in `max` (and in high percentiles once they are ≥1% of the sample
//! set) instead of aborting `BENCH_*.json` emission mid-run.

/// Summary of a sample set (times in seconds, or any unit).
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub median: f64,
    pub min: f64,
    pub max: f64,
    pub stddev: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "empty sample set");
        let n = samples.len();
        let mut sorted = samples.to_vec();
        sorted.sort_by(f64::total_cmp);
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / (n.max(2) - 1) as f64;
        Summary {
            n,
            mean,
            median: percentile(&sorted, 50.0),
            min: sorted[0],
            max: sorted[n - 1],
            stddev: var.sqrt(),
            p95: percentile(&sorted, 95.0),
            p99: percentile(&sorted, 99.0),
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted slice.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Geometric mean (used for speedup aggregation across workloads).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Ordinary least squares fit of log(y) = a + b*log(x); returns the
/// exponent b. Used by the complexity-scaling bench to estimate whether
/// step cost grows ~d^2 (OFTv2) or ~d^3 (weight-centric OFT).
pub fn loglog_slope(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let lx: Vec<f64> = xs.iter().map(|x| x.ln()).collect();
    let ly: Vec<f64> = ys.iter().map(|y| y.ln()).collect();
    let mx = lx.iter().sum::<f64>() / lx.len() as f64;
    let my = ly.iter().sum::<f64>() / ly.len() as f64;
    let num: f64 = lx.iter().zip(&ly).map(|(x, y)| (x - mx) * (y - my)).sum();
    let den: f64 = lx.iter().map(|x| (x - mx) * (x - mx)).sum();
    num / den
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.median - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.stddev - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let s = [0.0, 10.0];
        assert!((percentile(&s, 50.0) - 5.0).abs() < 1e-12);
        assert!((percentile(&s, 95.0) - 9.5).abs() < 1e-12);
        assert!((percentile(&s, 99.0) - 9.9).abs() < 1e-12);
    }

    #[test]
    fn nan_samples_never_panic_the_summary() {
        // Regression: one NaN sample used to abort the whole bench run
        // via `partial_cmp().unwrap()`. Under total order the summary
        // still computes, and the NaN lands in `max` (sorted last)
        // while the finite order statistics stay meaningful.
        let s = Summary::of(&[2.0, f64::NAN, 1.0, 3.0]);
        assert_eq!(s.n, 4);
        assert_eq!(s.min, 1.0);
        assert!((s.median - 2.5).abs() < 1e-12);
        assert!(s.max.is_nan(), "NaN must surface in max, got {}", s.max);
        assert!(s.mean.is_nan());
        // All-NaN degenerates without panicking either.
        let all = Summary::of(&[f64::NAN, f64::NAN]);
        assert!(all.min.is_nan() && all.max.is_nan());
    }

    #[test]
    fn geomean_of_ratios() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn loglog_slope_quadratic() {
        let xs = [1.0, 2.0, 4.0, 8.0];
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x * x).collect();
        assert!((loglog_slope(&xs, &ys) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn summary_order_invariants() {
        crate::testkit::check("summary ordering", 50, |g| {
            let n = g.usize_in(1, 40);
            let xs: Vec<f64> = (0..n).map(|_| g.f32_in(0.0, 100.0) as f64).collect();
            let s = Summary::of(&xs);
            if !(s.min <= s.median && s.median <= s.p95 + 1e-12 && s.p95 <= s.max) {
                return Err(format!("{s:?}"));
            }
            if s.mean < s.min - 1e-9 || s.mean > s.max + 1e-9 {
                return Err(format!("mean out of range: {s:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn loglog_slope_cubic() {
        let xs = [1.0, 2.0, 4.0, 8.0];
        let ys: Vec<f64> = xs.iter().map(|x| 0.5 * x * x * x).collect();
        assert!((loglog_slope(&xs, &ys) - 3.0).abs() < 1e-9);
    }
}
