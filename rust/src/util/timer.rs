//! Wall-clock timing helpers.

use std::time::Instant;

/// A simple scope timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer {
            start: Instant::now(),
        }
    }

    /// Elapsed seconds since start.
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Elapsed milliseconds since start.
    pub fn ms(&self) -> f64 {
        self.secs() * 1e3
    }

    pub fn reset(&mut self) {
        self.start = Instant::now();
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.secs())
}

/// Exponential moving average (step-time smoothing in the train loop).
#[derive(Clone, Debug)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0);
        Ema { alpha, value: None }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(v) => v + self.alpha * (x - v),
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.secs() >= 0.004);
    }

    #[test]
    fn timed_returns_value() {
        let (v, s) = timed(|| 42);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        assert_eq!(e.update(10.0), 10.0);
        let mut v = 0.0;
        for _ in 0..50 {
            v = e.update(20.0);
        }
        assert!((v - 20.0).abs() < 1e-3);
    }
}
