//! Full-state checkpoint roundtrip: save → load must restore the
//! trainables AND Adam moments bitwise and reproduce the next training
//! step exactly, for every PEFT method of the paper on the tiny preset.

use oftv2::artifacts_root;
use oftv2::config::RunCfg;
use oftv2::coordinator::{Manifest, Trainer};
use oftv2::runtime::Engine;

fn cfg(tag: &str, steps: usize) -> RunCfg {
    let mut c = RunCfg::default();
    c.tag = tag.into();
    c.steps = steps;
    c.log_every = 0;
    c.data.task = "math".into();
    c.data.documents = 200;
    c.optim.lr = 2e-3;
    c
}

#[test]
fn full_checkpoint_roundtrip_is_bitwise_for_every_method() {
    // Every *registered* method (quantized ones on the NF4 backend):
    // boft/hoft and any future registration get the same bitwise
    // save/resume lock with no list to update here.
    let e = Engine::cpu().unwrap();
    for tag in &oftv2::adapters::bundle_tags("tiny") {
        let steps = 4;
        let mut tr = Trainer::new(&e, &artifacts_root(), cfg(tag, steps)).unwrap();
        tr.train().unwrap();

        // Save the FULL state (weights + Adam moments + step) to disk.
        let path = std::env::temp_dir().join(format!(
            "oft_roundtrip_{}_{}.ckpt",
            std::process::id(),
            tag
        ));
        let ck = tr.checkpoint_full().unwrap();
        oftv2::coordinator::checkpoint::save(&path, &ck).unwrap();
        let loaded = oftv2::coordinator::checkpoint::load(&path).unwrap();
        assert_eq!(loaded, ck, "{tag}: checkpoint file roundtrip changed tensors");

        // Restore into a fresh trainer.
        let man = Manifest::load_or_builtin(artifacts_root().join(tag)).unwrap();
        let mut tr2 = Trainer::with_checkpoint(&e, man, cfg(tag, steps), Some(&loaded)).unwrap();

        // Trainables and both Adam moments must be bitwise identical.
        assert_eq!(tr2.step_count(), steps, "{tag}: step counter not restored");
        let (w1, w2) = (tr.trainable_tensors().unwrap(), tr2.trainable_tensors().unwrap());
        assert_eq!(w1.len(), w2.len());
        for ((n1, t1), (n2, t2)) in w1.iter().zip(&w2) {
            assert_eq!(n1, n2);
            assert!(
                bitwise_eq(&t1.data, &t2.data),
                "{tag}: trainable '{n1}' not bitwise after restore"
            );
        }
        let (m1, m2) = (tr.adam_moments().unwrap(), tr2.adam_moments().unwrap());
        for ((n1, ma, va), (n2, mb, vb)) in m1.iter().zip(&m2) {
            assert_eq!(n1, n2);
            assert!(bitwise_eq(&ma.data, &mb.data), "{tag}: adam m '{n1}' differs");
            assert!(bitwise_eq(&va.data, &vb.data), "{tag}: adam v '{n1}' differs");
        }

        // The SAME next batch must produce the identical next-step loss.
        let batch = tr.loader.next_batch();
        let loss_a = tr.train_on(&batch).unwrap();
        let loss_b = tr2.train_on(&batch).unwrap();
        assert!(
            loss_a.to_bits() == loss_b.to_bits(),
            "{tag}: next-step loss diverged after restore ({loss_a} vs {loss_b})"
        );

        let _ = std::fs::remove_file(path);
    }
}

#[test]
fn dropout_resume_is_bitwise() {
    // Module dropout draws its decisions from (seed, step, name) alone,
    // so persisting the ScenarioCfg (incl. the seed) plus the step
    // counter IS the full RNG state: a resumed run must replay the
    // exact dropout pattern and reproduce the next step bitwise.
    let tag = "tiny_oft_v2+dropout=0.35+dropout_seed=7";
    let e = Engine::cpu().unwrap();
    let mut tr = Trainer::new(&e, &artifacts_root(), cfg(tag, 4)).unwrap();
    tr.train().unwrap();
    let ck = tr.checkpoint_full().unwrap();
    assert!(
        ck.get(oftv2::scenario::CKPT_KEY).is_some(),
        "full checkpoint must persist the scenario config"
    );

    let man = Manifest::load_or_builtin(artifacts_root().join(tag)).unwrap();
    let mut tr2 = Trainer::with_checkpoint(&e, man, cfg(tag, 4), Some(&ck)).unwrap();
    assert_eq!(tr2.step_count(), 4);
    let batch = tr.loader.next_batch();
    let la = tr.train_on(&batch).unwrap();
    let lb = tr2.train_on(&batch).unwrap();
    assert_eq!(
        la.to_bits(),
        lb.to_bits(),
        "dropout resume diverged: {la} vs {lb}"
    );
}

#[test]
fn scenario_mismatch_on_resume_is_rejected() {
    // A checkpoint trained under one scenario must not silently resume
    // under another — dropout/COFT/targeting change the trajectory.
    let trained = "tiny_oft_v2+dropout=0.35";
    let e = Engine::cpu().unwrap();
    let mut tr = Trainer::new(&e, &artifacts_root(), cfg(trained, 2)).unwrap();
    tr.train().unwrap();
    let ck = tr.checkpoint_full().unwrap();

    let man = Manifest::load_or_builtin(artifacts_root().join("tiny_oft_v2")).unwrap();
    let err = match Trainer::with_checkpoint(&e, man, cfg("tiny_oft_v2", 2), Some(&ck)) {
        Err(e) => format!("{e:#}"),
        Ok(_) => panic!("resume under a different scenario should fail"),
    };
    assert!(
        err.contains("resume with the same scenario knobs"),
        "mismatch error should explain the fix: {err}"
    );
}

#[test]
fn weights_only_checkpoint_still_resets_optimizer() {
    // The init-style checkpoint (no __adam_* entries) must keep the old
    // semantics: weights restore, moments and step start fresh.
    let e = Engine::cpu().unwrap();
    let mut tr = Trainer::new(&e, &artifacts_root(), cfg("tiny_oft_v2", 4)).unwrap();
    tr.train().unwrap();
    let ck = tr.checkpoint().unwrap();
    assert!(ck.keys().all(|k| !k.starts_with("__")));

    let man = Manifest::load_or_builtin(artifacts_root().join("tiny_oft_v2")).unwrap();
    let tr2 = Trainer::with_checkpoint(&e, man, cfg("tiny_oft_v2", 4), Some(&ck)).unwrap();
    assert_eq!(tr2.step_count(), 0);
    for (name, m, v) in tr2.adam_moments().unwrap() {
        assert!(
            m.data.iter().all(|&x| x == 0.0) && v.data.iter().all(|&x| x == 0.0),
            "moments of '{name}' should start at zero from a weights-only checkpoint"
        );
    }
}

#[test]
fn sharded_checkpoints_reassemble_byte_identical() {
    use std::sync::Arc;
    use std::time::Duration;

    use oftv2::comms::RankGroup;
    use oftv2::coordinator::checkpoint::{self, shard_checkpoint_path};

    let tag = "tiny_oft_v2";
    let steps = 4;

    // Oracle: the classic single-process run and its full checkpoint.
    let e = Engine::cpu().unwrap();
    let mut solo = Trainer::new(&e, &artifacts_root(), cfg(tag, steps)).unwrap();
    solo.train().unwrap();
    let oracle = solo.checkpoint_full().unwrap();

    // A 2-rank in-process group; each rank produces only its shard.
    let ranks = 2usize;
    let groups = RankGroup::mem_mesh(ranks, Duration::from_secs(60));
    let shards: Vec<oftv2::coordinator::Checkpoint> = std::thread::scope(|s| {
        let handles: Vec<_> = groups
            .into_iter()
            .map(|g| {
                s.spawn(move || {
                    let e = Engine::cpu().unwrap();
                    let mut c = cfg(tag, steps);
                    c.train.ranks = ranks;
                    let mut tr = Trainer::new(&e, &artifacts_root(), c).unwrap();
                    tr.connect_ranks(Arc::new(g)).unwrap();
                    tr.train().unwrap();
                    tr.checkpoint_shard().unwrap()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    // Round-trip each shard through its `.rank<r>of<R>` file, then the
    // reassembled checkpoint's file must be byte-identical to the full
    // single-process save.
    let base = std::env::temp_dir().join(format!("oft_shard_rt_{}.ckpt", std::process::id()));
    checkpoint::save(&base, &oracle).unwrap();
    let mut parts = Vec::new();
    for (r, shard) in shards.iter().enumerate() {
        let p = shard_checkpoint_path(&base, r, ranks);
        checkpoint::save(&p, shard).unwrap();
        parts.push(checkpoint::load(&p).unwrap());
        let _ = std::fs::remove_file(p);
    }
    let man = Manifest::load_or_builtin(artifacts_root().join(tag)).unwrap();
    let reassembled = checkpoint::reassemble_sharded(&man, &parts).unwrap();
    let repath = base.with_extension("ckpt.reassembled");
    checkpoint::save(&repath, &reassembled).unwrap();
    assert_eq!(
        std::fs::read(&repath).unwrap(),
        std::fs::read(&base).unwrap(),
        "reassembled sharded checkpoint is not byte-identical to the full save"
    );

    // Resuming from the reassembled state reproduces the oracle's next
    // step bitwise.
    let man_a = Manifest::load_or_builtin(artifacts_root().join(tag)).unwrap();
    let mut tr_a = Trainer::with_checkpoint(&e, man_a, cfg(tag, steps), Some(&oracle)).unwrap();
    let man_b = Manifest::load_or_builtin(artifacts_root().join(tag)).unwrap();
    let mut tr_b =
        Trainer::with_checkpoint(&e, man_b, cfg(tag, steps), Some(&reassembled)).unwrap();
    let batch = tr_a.loader.next_batch();
    let la = tr_a.train_on(&batch).unwrap();
    let lb = tr_b.train_on(&batch).unwrap();
    assert_eq!(la.to_bits(), lb.to_bits(), "resume diverged: {la} vs {lb}");

    let _ = std::fs::remove_file(base);
    let _ = std::fs::remove_file(repath);
}

fn bitwise_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}
