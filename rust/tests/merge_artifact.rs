//! Adapter lifecycle integration: train → merge → (re)quantize →
//! versioned artifact → serve hot-load.
//!
//! The acceptance locks:
//!   * for every mergeable registry method, a `QuantKind::None` artifact
//!     decodes token-for-token what the live adapter decodes over the
//!     same base;
//!   * NF4 re-quantized merges stay within the documented tolerance
//!     contract recorded in the artifact's per-linear stats;
//!   * hot-loading artifacts through the pager never re-uploads —
//!     `Engine::upload_count()` stays flat across page-ins.

use std::sync::Arc;

use oftv2::artifact::{self, merge_checkpoint};
use oftv2::artifacts_root;
use oftv2::config::RunCfg;
use oftv2::coordinator::{BaseModel, Manifest, Trainer};
use oftv2::quant::requant::QuantKind;
use oftv2::runtime::Engine;
use oftv2::serve::{ServeConfig, Server};

fn cfg(tag: &str, steps: usize) -> RunCfg {
    let mut c = RunCfg::default();
    c.tag = tag.into();
    c.steps = steps;
    c.log_every = 0;
    c.data.task = "math".into();
    c.data.documents = 200;
    c.optim.lr = 3e-3;
    c
}

fn man(tag: &str) -> Manifest {
    Manifest::load_or_builtin(artifacts_root().join(tag)).unwrap()
}

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("oft_merge_it_{}_{name}", std::process::id()))
}

/// Submit one request and drain the server; returns its response.
fn run_one(
    srv: &mut Server<'_>,
    adapter: &str,
    prompt: Vec<i32>,
    max_new: usize,
) -> oftv2::serve::Response {
    let id = srv.submit(adapter, prompt, max_new).unwrap();
    let rs = srv.run_until_idle().unwrap();
    assert_eq!(rs.len(), 1);
    assert_eq!(rs[0].id, id);
    rs[0].clone()
}

#[test]
fn merged_artifact_decode_matches_live_token_for_token() {
    // The lifecycle lock: for EVERY registered method, train a few
    // steps, export the checkpoint, fold it into an f32 artifact
    // (quant = none), round-trip the artifact through disk, hot-load it
    // next to the live adapter — and require greedy decode to agree
    // token for token. Quantized-base bundles join the same lock
    // because the merge runs against the NF4 round trip of the master,
    // i.e. exactly the values the fused kernels decoded with.
    let e = Engine::reference();
    let seed = 42u64; // RunCfg::default().seed, so solo trainers agree
    let base = BaseModel::for_preset(&e, "tiny", seed, None).unwrap();
    let prompts = [vec![1i32, 9, 4], vec![2, 7]];

    for tag in &oftv2::adapters::bundle_tags("tiny") {
        let mut tr =
            Trainer::with_base(&e, man(tag), cfg(tag, 6), None, Arc::clone(&base)).unwrap();
        tr.train().unwrap(); // non-trivial adapter weights
        let ckpt = tr.checkpoint().unwrap();

        let art = merge_checkpoint(&man(tag), &ckpt, seed, QuantKind::None).unwrap();
        assert_eq!(&art.source_tag, tag);
        assert_eq!(art.method, man(tag).method);

        // Deploy through the versioned file format, not the in-memory
        // object — the artifact a real fleet would hot-load.
        let path = tmp(tag);
        artifact::save(&path, &art).unwrap();
        let art = artifact::load(&path).unwrap();
        let _ = std::fs::remove_file(&path);

        let mut srv = Server::new(&e, Arc::clone(&base), 2);
        srv.add_adapter_init("live", man(tag), seed, Some(&ckpt)).unwrap();
        srv.add_artifact("merged", &art).unwrap();
        assert_eq!(srv.merged_adapters(), 1);

        for p in &prompts {
            let live = run_one(&mut srv, "live", p.clone(), 8);
            let merged = run_one(&mut srv, "merged", p.clone(), 8);
            assert_eq!(
                merged.tokens, live.tokens,
                "{tag}: merged artifact diverged from the live adapter on {p:?}"
            );
        }
    }
}

#[test]
fn nf4_requant_tolerances_hold_and_artifact_serves() {
    // The documented tolerance contract for NF4 re-quantized merges of
    // the quantized-base bundles (README "Adapter lifecycle"):
    //   * baseline_rms < 5e-4 on packed linears — re-quantizing an
    //     already-NF4 base costs only double-quantization drift, an
    //     order of magnitude under the fresh-quantization floor;
    //   * merged_rms < 5e-3 and merged_max < 5e-2 — the trained merge
    //     re-quantizes near the baseline floor, not catastrophically;
    //   * range_inflation in (0.7, 1.35) — §4's bounded-range property.
    let e = Engine::reference();
    let seed = 42u64;
    let base = BaseModel::for_preset(&e, "tiny", seed, None).unwrap();

    for tag in ["tiny_qlora_nf4", "tiny_qoft_nf4"] {
        let mut tr =
            Trainer::with_base(&e, man(tag), cfg(tag, 6), None, Arc::clone(&base)).unwrap();
        tr.train().unwrap();
        let ckpt = tr.checkpoint().unwrap();

        let art = merge_checkpoint(&man(tag), &ckpt, seed, QuantKind::Nf4).unwrap();
        assert_eq!(art.quant, QuantKind::Nf4);
        let packed = man(tag).quantized_bases();
        let mut max_delta = 0.0f64;
        for s in &art.stats {
            if packed.iter().any(|b| b == &s.linear) {
                assert!(
                    s.baseline_rms < 5e-4,
                    "{tag}/{}: re-quantizing the already-NF4 base should be \
                     near-lossless, got baseline_rms {}",
                    s.linear,
                    s.baseline_rms
                );
            }
            assert!(
                s.merged_rms < 5e-3,
                "{tag}/{}: merged_rms {} breaks the documented tolerance",
                s.linear,
                s.merged_rms
            );
            assert!(
                s.merged_max < 5e-2,
                "{tag}/{}: merged_max {} breaks the documented tolerance",
                s.linear,
                s.merged_max
            );
            assert!(
                s.range_inflation > 0.7 && s.range_inflation < 1.35,
                "{tag}/{}: range_inflation {} outside (0.7, 1.35)",
                s.linear,
                s.range_inflation
            );
            max_delta = max_delta.max(s.delta_inf);
        }
        assert!(
            max_delta > 0.0,
            "{tag}: training must move at least one merged linear off the base"
        );

        // The NF4-deployed artifact still serves: valid in-vocab tokens
        // through the same hot-load path.
        let mut srv = Server::new(&e, Arc::clone(&base), 2);
        srv.add_artifact("m", &art).unwrap();
        let vocab = srv.vocab_of("m").unwrap() as i32;
        let r = run_one(&mut srv, "m", vec![1, 9, 4], 8);
        assert!(!r.tokens.is_empty());
        assert!(r.tokens.iter().all(|&t| t >= 0 && t < vocab));
    }
}

#[test]
fn artifact_hot_loads_stay_upload_flat() {
    // Paging merged artifacts in and out must rebuild their decoders
    // from each private base's cached buffers — zero uploads after the
    // initial attach, exactly like live-adapter hot-swap.
    let e = Engine::reference();
    let seed = 42u64;
    let base = BaseModel::for_preset(&e, "tiny", seed, None).unwrap();

    let mut arts = Vec::new();
    for tag in ["tiny_oft_v2", "tiny_lora"] {
        // A checkpoint at init (identity adapters) is enough to exercise
        // the paging path.
        let tr = Trainer::with_base(&e, man(tag), cfg(tag, 0), None, Arc::clone(&base)).unwrap();
        let ckpt = tr.checkpoint().unwrap();
        arts.push(merge_checkpoint(&man(tag), &ckpt, seed, QuantKind::None).unwrap());
    }

    let mut c = ServeConfig::new(2);
    c.max_resident = Some(1); // force page-ins across 3 residents
    let mut srv = Server::with_config(&e, Arc::clone(&base), c);
    srv.add_adapter_init("live", man("tiny_boft"), seed, None).unwrap();
    srv.add_artifact("m1", &arts[0]).unwrap();
    srv.add_artifact("m2", &arts[1]).unwrap();
    assert_eq!(srv.merged_adapters(), 2);
    assert!(srv.resident_adapters() <= 1, "cap enforced while idle");

    let uploads = e.upload_count();
    for round in 0..3 {
        for name in ["m1", "live", "m2"] {
            let r = run_one(&mut srv, name, vec![1, (round + 5) as i32], 4);
            assert!(!r.tokens.is_empty());
        }
    }
    assert_eq!(
        e.upload_count(),
        uploads,
        "artifact page-ins must rebuild from cached buffers, never re-upload"
    );
    let m = srv.metrics();
    assert!(
        m.adapter_page_ins > 0 && m.adapter_evictions > 0,
        "3 residents over a cap of 1 must page (page_ins={}, evictions={})",
        m.adapter_page_ins,
        m.adapter_evictions
    );

    // Guard rails: duplicate names and wrong presets are rejected.
    let err = srv.add_artifact("m1", &arts[0]).unwrap_err().to_string();
    assert!(err.contains("already registered"), "{err}");
}
