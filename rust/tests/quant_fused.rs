//! Fused NF4/AWQ matmul + gemv kernels locked against the
//! `dequantize()`-then-`matmul` oracle, and the full model locked the
//! same way across the PEFT methods.
//!
//! Tolerances (documented contract):
//! * **gemv** (one activation row, the decode path): asserted *exactly*
//!   equal — the fused kernel accumulates every output element over the
//!   contraction index in ascending order, matching `Tensor::matmul`.
//! * **blocked matmul** (multi-row): asserted to 1e-5 abs + 1e-5 rel.
//!   Today the blocked path is also exact (same per-element order at
//!   every thread count); the slack is headroom for future re-blocking
//!   of the kernels, not an observed error.

use std::collections::BTreeMap;

use oftv2::coordinator::{BundleState, Manifest};
use oftv2::quant::{AwqTensor, Nf4Tensor, QuantWeight};
use oftv2::runtime::refmodel::{Params, RefBundle};
use oftv2::tensor::Tensor;
use oftv2::testkit;
use oftv2::util::rng::Rng;

fn qweight(kind: &str, din: usize, dout: usize, seed: u64) -> QuantWeight {
    let mut rng = Rng::new(seed);
    let w = Tensor::randn(&[din, dout], 0.1, &mut rng);
    match kind {
        "nf4" => QuantWeight::nf4(Nf4Tensor::quantize(&w)).unwrap(),
        "awq" => QuantWeight::awq(AwqTensor::quantize(&w, None).unwrap()).unwrap(),
        other => panic!("unknown kind {other}"),
    }
}

#[test]
fn fused_gemv_is_exactly_the_oracle() {
    // m = 1 is the KV-decode hot path: one row per token per linear.
    testkit::check("fused gemv == dequantize-then-matmul", 40, |g| {
        let kind = *g.choose(&["nf4", "awq"]);
        let din = *g.choose(&[64usize, 128, 192, 320]);
        let dout = *g.choose(&[16usize, 48, 96]);
        let qw = qweight(kind, din, dout, g.rng.next_u64());
        let oracle = qw.dequantize();
        let mut rng = Rng::new(g.rng.next_u64());
        let x = Tensor::randn(&[1, din], 1.0, &mut rng);
        let fused = qw.matmul(&x).map_err(|e| e.to_string())?;
        let want = x.matmul(&oracle).map_err(|e| e.to_string())?;
        if fused != want {
            return Err(format!("{kind} gemv diverged at ({din},{dout})"));
        }
        let gy = Tensor::randn(&[1, dout], 1.0, &mut rng);
        let fused_t = qw.matmul_t(&gy).map_err(|e| e.to_string())?;
        let want_t = gy.matmul(&oracle.transpose2()).map_err(|e| e.to_string())?;
        if fused_t != want_t {
            return Err(format!("{kind} gemv^T diverged at ({din},{dout})"));
        }
        Ok(())
    });
}

#[test]
fn fused_blocked_matmul_within_documented_tolerance() {
    testkit::check("fused blocked matmul vs oracle", 30, |g| {
        let kind = *g.choose(&["nf4", "awq"]);
        let din = *g.choose(&[64usize, 128, 384]);
        let dout = *g.choose(&[32usize, 80]);
        let m = g.usize_in(2, 40);
        let qw = qweight(kind, din, dout, g.rng.next_u64());
        let oracle = qw.dequantize();
        let mut rng = Rng::new(g.rng.next_u64());
        let x = Tensor::randn(&[m, din], 1.0, &mut rng);
        let fused = qw.matmul(&x).map_err(|e| e.to_string())?;
        let want = x.matmul(&oracle).map_err(|e| e.to_string())?;
        testkit::assert_allclose(&fused.data, &want.data, 1e-5, 1e-5)?;
        let gy = Tensor::randn(&[m, dout], 1.0, &mut rng);
        let fused_t = qw.matmul_t(&gy).map_err(|e| e.to_string())?;
        let want_t = gy.matmul(&oracle.transpose2()).map_err(|e| e.to_string())?;
        testkit::assert_allclose(&fused_t.data, &want_t.data, 1e-5, 1e-5)
    });
}

/// Build (fused, oracle) Params for a bundle from one BundleState: the
/// fused variant carries the packs as `QuantWeight`s; the oracle
/// variant carries the same packs dequantized to dense f32 (the exact
/// tensors the pre-fused engine assembled).
fn params_pair(man: &Manifest, st: &BundleState) -> (Params, Params) {
    let mut map: BTreeMap<String, Tensor> = BTreeMap::new();
    for (spec, t) in man.trainable.iter().zip(&st.trainable) {
        map.insert(spec.name.clone(), t.clone());
    }
    for (spec, v) in man.frozen.iter().zip(&st.fixed[..man.frozen.len()]) {
        map.insert(
            spec.name.clone(),
            Tensor::from_vec(&spec.shape, v.f32s().unwrap().to_vec()),
        );
    }
    let mut quant: BTreeMap<String, QuantWeight> = BTreeMap::new();
    let mut oracle_map = map.clone();
    for (base, w) in &st.quantized_bases {
        let qw = match man.quant.as_str() {
            "nf4" => QuantWeight::nf4(Nf4Tensor::quantize(w)).unwrap(),
            "awq" => QuantWeight::awq(AwqTensor::quantize(w, None).unwrap()).unwrap(),
            other => panic!("unexpected quant '{other}'"),
        };
        oracle_map.insert(base.clone(), qw.dequantize());
        quant.insert(base.clone(), qw);
    }
    (
        Params { map, quant },
        Params {
            map: oracle_map,
            quant: BTreeMap::new(),
        },
    )
}

#[test]
fn model_loss_and_grads_locked_to_dequantize_oracle_across_methods() {
    // Every PEFT method's loss + gradients through the fused path must
    // match the dequantize-then-dense path. For the 5 full-precision
    // methods the two parameter sets are identical (locks the Params
    // plumbing); for the 4 quantized variants (QLoRA/QOFT x NF4/AWQ)
    // this is the real fused-vs-oracle lock, through the entire
    // forward + backward.
    for tag in [
        "tiny_full",
        "tiny_none",
        "tiny_lora",
        "tiny_oft_merged",
        "tiny_oft_v2",
        "tiny_qlora_nf4",
        "tiny_qoft_nf4",
        "tiny_qlora_awq",
        "tiny_qoft_awq",
    ] {
        let man = Manifest::builtin(tag).unwrap();
        let bu = RefBundle::from_manifest(&man).unwrap();
        let st = BundleState::init(&man, 7, None).unwrap();
        let (fused, oracle) = params_pair(&man, &st);

        let (b, t) = (man.model.batch, man.model.seq_len);
        let mut rng = Rng::new(17);
        let tokens: Vec<i32> = (0..b * (t + 1))
            .map(|_| rng.below(man.model.vocab) as i32)
            .collect();
        let mask = vec![1.0f32; b * t];

        let (lf, gf) = bu.loss_and_grads(&fused, &tokens, &mask).unwrap();
        let (lo, go) = bu.loss_and_grads(&oracle, &tokens, &mask).unwrap();
        assert!(
            (lf - lo).abs() <= 1e-6,
            "{tag}: fused loss {lf} vs oracle loss {lo}"
        );
        assert_eq!(gf.len(), go.len(), "{tag}: gradient key sets differ");
        for (name, g) in &gf {
            let o = &go[name];
            let diff = g.max_abs_diff(o);
            assert!(diff <= 1e-5, "{tag}: grad '{name}' diff {diff}");
        }
    }
}
