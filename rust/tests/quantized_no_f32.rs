//! The QOFT/QLoRA memory guarantee, end to end: with `--quant nf4` or
//! `--quant awq`, no full f32 copy of any base weight matrix enters
//! the *compute path* during train / eval / decode / serve — the
//! engine-resident base is the packs, and nothing ever expands them.
//! (The one f32 form that legitimately exists is `BaseModel`'s
//! load-time host master — the quantization source and checkpoint
//! export, exactly the copy a real QLoRA loader reads before packing;
//! it is never uploaded for quantized bases and never consulted by a
//! forward/backward/decode step.)
//!
//! Two probes, in the spirit of `Engine::upload_count`:
//! * `quant::dequant_f32_count()` — every packed→f32 expansion
//!   increments it; the fused kernels never do. This file keeps all
//!   intentional oracle dequantization out, so the counter must stay
//!   flat across every quantized flow (the process-wide assertion is
//!   why these tests live in their own integration binary).
//! * `Engine::upload_bytes()` — a quantized bundle's fixed inputs
//!   upload at the packed size, within 1.5x of the manifest's pack
//!   bytes and far below the f32 base.

use std::sync::Arc;

use oftv2::artifacts_root;
use oftv2::config::RunCfg;
use oftv2::coordinator::{BaseModel, Manifest, Trainer};
use oftv2::quant::dequant_f32_count;
use oftv2::runtime::{CheckpointPolicy, Engine};
use oftv2::serve::Server;

fn cfg(tag: &str, steps: usize) -> RunCfg {
    let mut c = RunCfg::default();
    c.tag = tag.into();
    c.steps = steps;
    c.log_every = 0;
    c.data.task = "math".into();
    c.data.documents = 120;
    c
}

fn man(tag: &str) -> Manifest {
    Manifest::load_or_builtin(artifacts_root().join(tag)).unwrap()
}

#[test]
fn quantized_flows_never_materialize_f32_base() {
    let e = Engine::reference();
    let before = dequant_f32_count();

    // Train (including checkpointed + multi-worker paths), eval, and
    // both decode paths, for every quantized bundle variant.
    for tag in [
        "tiny_qlora_nf4",
        "tiny_qoft_nf4",
        "tiny_qlora_awq",
        "tiny_qoft_awq",
    ] {
        let mut c = cfg(tag, 2);
        if tag == "tiny_qoft_nf4" {
            c.train.grad_checkpoint = CheckpointPolicy::EveryK(1);
            c.train.workers = 2;
        }
        let mut tr = Trainer::new(&e, &artifacts_root(), c).unwrap();
        tr.train().unwrap();
        tr.evaluate().unwrap();
        tr.decode_greedy(&[1, 5, 9], 4).unwrap();
        tr.decode_greedy_reforward(&[1, 5, 9], 4).unwrap();
    }

    // Serve: NF4 and AWQ adapters batched over one shared base. Built
    // with `from_manifest` from a *quantized* manifest, so the engine
    // never holds f32 buffers for the base linears at all — a
    // quantized-only fleet is packed-only even engine-side. (The
    // `for_preset` base used by mixed fleets deliberately uploads f32
    // base buffers so full-precision adapters can attach too.)
    let qman = man("tiny_qoft_nf4");
    let base = BaseModel::from_manifest(&e, &qman, 7, None).unwrap();
    let serve_bytes0 = e.upload_bytes();
    let mut srv = Server::new(&e, Arc::clone(&base), 2);
    srv.add_adapter_init("qoft", qman.clone(), 7, None).unwrap();
    srv.add_adapter_init("qlora", man("tiny_qlora_awq"), 7, None).unwrap();
    srv.submit("qoft", vec![1, 2, 3], 4).unwrap();
    srv.submit("qlora", vec![1, 4], 4).unwrap();
    srv.submit("qoft", vec![2], 3).unwrap();
    let responses = srv.run_until_idle().unwrap();
    assert_eq!(responses.len(), 3);
    // Attaching both adapters uploaded exactly the two pack sets (NF4
    // + AWQ) — no f32 base entered the engine for serving.
    let serve_uploaded = e.upload_bytes() - serve_bytes0;
    let packs_both = qman.quantized_pack_bytes() + man("tiny_qlora_awq").quantized_pack_bytes();
    assert!(
        serve_uploaded <= packs_both + packs_both / 2,
        "serve attach uploaded {serve_uploaded} B, packs are {packs_both} B"
    );

    assert_eq!(
        dequant_f32_count(),
        before,
        "a packed base weight was expanded to a full f32 tensor"
    );
}

#[test]
fn quantized_fixed_inputs_upload_at_packed_size() {
    let e = Engine::reference();
    for tag in ["tiny_qoft_nf4", "tiny_qlora_awq"] {
        let m = man(tag);
        let base = BaseModel::from_manifest(&e, &m, 7, None).unwrap();
        let before = e.upload_bytes();
        let _fixed = base.fixed_for(&e, &m).unwrap();
        let measured = e.upload_bytes() - before;
        let packed = m.quantized_pack_bytes();
        assert!(
            measured <= packed + packed / 2,
            "{tag}: base residency {measured} B exceeds 1.5x packed {packed} B"
        );
        let f32b = m.dequantized_base_bytes().unwrap();
        assert!(
            measured < f32b,
            "{tag}: packed residency {measured} B not below f32 {f32b} B"
        );
    }
}
