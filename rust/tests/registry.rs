//! The open-registry contracts: (a) parse / list / count / memmodel
//! agree for every registered PEFT method, and (b) the two methods the
//! registry was proven with — BOFT and HOFT — run end-to-end (train,
//! eval, KV-decode vs. the re-forward oracle, serve, checkpoint
//! resume) selected purely by bundle tag. CI runs this file in release
//! mode alongside the scaling-invariant locks.

use std::sync::Arc;

use oftv2::adapters;
use oftv2::artifacts_root;
use oftv2::config::RunCfg;
use oftv2::coordinator::{manifest::parse_tag, BaseModel, Manifest, Trainer};
use oftv2::memmodel::{self, Precision, TrainShape};
use oftv2::modelspec::ModelSpec;
use oftv2::peft::counting::{count_with, MethodKind};
use oftv2::runtime::Engine;
use oftv2::serve::Server;

fn cfg(tag: &str, steps: usize) -> RunCfg {
    let mut c = RunCfg::default();
    c.tag = tag.into();
    c.steps = steps;
    c.log_every = 0;
    c.data.task = "math".into();
    c.data.documents = 200;
    c.optim.lr = 3e-3;
    c
}

#[test]
fn registry_parse_list_count_memmodel_agree() {
    let spec = ModelSpec::llama2_7b();
    let names = adapters::names();
    assert!(names.len() >= 9, "registry lost methods: {names:?}");
    for adapter in adapters::all() {
        let name = adapter.name();
        // list -> parse roundtrip
        assert_eq!(adapters::get(name).unwrap().name(), name);

        // tag parsing resolves every registered method
        let tag = adapters::bundle_tag("tiny", *adapter);
        let (preset, method, quant) = parse_tag(&tag).unwrap();
        assert_eq!(preset, "tiny");
        assert_eq!(method, name);
        assert_eq!(quant != "none", adapter.quantized_base(), "{name}");

        // manifest synthesis agrees with the adapter's own declaration
        let man = Manifest::builtin(&tag).unwrap();
        assert_eq!(man.method, name);
        assert_eq!(man.trainable_numel(), man.params_trainable, "{name}");
        if !adapter.trains_base() {
            let declared: u64 = oftv2::coordinator::manifest::adapted_linear_dims(&man.model)
                .iter()
                .flat_map(|(n, din, dout)| adapter.linear_trainables(n, *din, *dout, &man.model))
                .map(|s| s.numel() as u64)
                .sum();
            assert_eq!(declared, man.params_trainable, "{name}: spec drift");
        }

        // counting and the memory model price the same declaration
        let kind = MethodKind::by_name(name, 16, 32).unwrap();
        let n_params = count_with(&spec, kind.adapter, &kind.dims);
        let method = memmodel::Method::by_name(name, 16, 32).unwrap();
        let mem = memmodel::finetune_memory(&spec, method, Precision::Bf16, TrainShape::default());
        assert!(
            (mem.adapter_params - n_params as f64 * 4.0).abs() < 1.0,
            "{name}: memmodel adapter bytes disagree with the registry count"
        );
        assert!(mem.total_gib().is_finite() && mem.total_gib() > 0.0, "{name}");
        assert!(!method.label(adapter.quantized_base()).is_empty());
    }

    // unknown methods error with the full registry list everywhere
    let err = format!("{:#}", parse_tag("tiny_warp").unwrap_err());
    for n in names {
        assert!(err.contains(n), "parse_tag error should list '{n}': {err}");
    }
}

#[test]
fn boft_and_hoft_train_eval_decode_checkpoint_end_to_end() {
    let e = Engine::cpu().unwrap();
    for tag in ["tiny_boft", "tiny_hoft"] {
        // Train: loss decreases and stays finite, selected purely by tag.
        let steps = 12;
        let mut tr = Trainer::new(&e, &artifacts_root(), cfg(tag, steps)).unwrap();
        let hist = tr.train().unwrap();
        let first = hist.first_loss().unwrap();
        let tail = hist.tail_loss(3).unwrap();
        assert!(tail < first, "{tag}: loss did not decrease ({first} -> {tail})");
        assert!(hist.steps.iter().all(|s| s.loss.is_finite()), "{tag}: NaN");

        // Eval: finite loss/perplexity over the held-out split.
        let (eval_loss, ppl) = tr.evaluate().unwrap();
        assert!(eval_loss.is_finite() && ppl.is_finite(), "{tag}");

        // KV decode locks token-for-token against the re-forward oracle.
        for prompt in [vec![1, 10, 20], vec![2], vec![1, 3, 5, 7, 9, 11]] {
            let old = tr.decode_greedy_reforward(&prompt, 12).unwrap();
            let new = tr.decode_greedy(&prompt, 12).unwrap();
            assert_eq!(old, new, "{tag}: KV decode diverged on {prompt:?}");
        }

        // Full-state checkpoint resume reproduces the next step bitwise.
        let ck = tr.checkpoint_full().unwrap();
        let man = Manifest::load_or_builtin(artifacts_root().join(tag)).unwrap();
        let mut tr2 = Trainer::with_checkpoint(&e, man, cfg(tag, steps), Some(&ck)).unwrap();
        assert_eq!(tr2.step_count(), steps, "{tag}: step counter not restored");
        let batch = tr.loader.next_batch();
        let a = tr.train_on(&batch).unwrap();
        let b = tr2.train_on(&batch).unwrap();
        assert_eq!(a.to_bits(), b.to_bits(), "{tag}: resume diverged ({a} vs {b})");
    }
}

#[test]
fn boft_and_hoft_serve_over_a_shared_base() {
    // Both new methods attach to one resident base next to the
    // existing methods and serve batched KV-decode requests that match
    // a solo decoder token-for-token.
    let e = Engine::reference();
    let seed = 42u64; // RunCfg::default().seed, so solo trainers agree
    let base = BaseModel::for_preset(&e, "tiny", seed, None).unwrap();
    let uploads_after_base = e.upload_count();

    let mut srv = Server::new(&e, Arc::clone(&base), 3);
    srv.add_adapter_init("boft", Manifest::builtin("tiny_boft").unwrap(), seed, None)
        .unwrap();
    srv.add_adapter_init("hoft", Manifest::builtin("tiny_hoft").unwrap(), seed, None)
        .unwrap();
    srv.add_adapter_init("v2", Manifest::builtin("tiny_oft_v2").unwrap(), seed, None)
        .unwrap();
    assert_eq!(
        e.upload_count(),
        uploads_after_base,
        "full-precision boft/hoft adapters must not re-upload the base"
    );

    let prompts: Vec<Vec<i32>> = vec![vec![1, 9, 4], vec![1, 30], vec![2, 2, 2]];
    for p in &prompts {
        for name in ["boft", "hoft", "v2"] {
            srv.submit(name, p.clone(), 8).unwrap();
        }
    }
    let responses = srv.run_until_idle().unwrap();
    assert_eq!(responses.len(), 3 * prompts.len());

    for (method, tag) in [("boft", "tiny_boft"), ("hoft", "tiny_hoft")] {
        let mut solo = Trainer::with_base(
            &e,
            Manifest::builtin(tag).unwrap(),
            cfg(tag, 0),
            None,
            Arc::clone(&base),
        )
        .unwrap();
        for (i, p) in prompts.iter().enumerate() {
            let served = responses
                .iter()
                .find(|r| r.adapter == method && r.prompt_len == p.len() && r.id as usize / 3 == i)
                .unwrap();
            assert_eq!(
                served.tokens,
                solo.decode_greedy(p, 8).unwrap(),
                "{method}: served decode diverged from solo on {p:?}"
            );
        }
    }
}
