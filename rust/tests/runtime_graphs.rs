//! Integration: bundle graphs and micro kernels executed through the
//! runtime engine, cross-checked against the host-side Rust oracles
//! (rust/src/peft, rust/src/quant).
//!
//! These run on the default (reference) engine with builtin bundles, so
//! `cargo test` exercises kernel-vs-oracle parity on a clean checkout —
//! no artifacts, no Python, no accelerator. The PJRT/HLO variants live
//! at the bottom behind `--features pjrt` (plus `make artifacts`).

use oftv2::coordinator::{BundleState, Manifest};
use oftv2::peft;
use oftv2::quant::{AwqTensor, Nf4Tensor};
use oftv2::runtime::micro::MicroCatalog;
use oftv2::runtime::{lit_f32, lit_i32, Engine};
use oftv2::tensor::Tensor;
use oftv2::util::rng::Rng;

fn engine() -> Engine {
    Engine::reference()
}

fn catalog() -> MicroCatalog {
    MicroCatalog::builtin()
}

fn manifest(tag: &str) -> Manifest {
    Manifest::builtin(tag).expect("builtin bundle")
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .fold(0.0f32, |m, (x, y)| m.max((x - y).abs()))
}

fn assert_finite(xs: &[f32], what: &str) {
    assert!(xs.iter().all(|x| x.is_finite()), "{what}: non-finite values");
}

// ---------------------------------------------------------------------------
// Micro kernels vs host oracles
// ---------------------------------------------------------------------------

#[test]
fn cnp_kernel_matches_host_oracle() {
    let e = engine();
    let cat = catalog();
    for name in ["cnp_b16", "cnp_b32"] {
        let k = cat.compile(&e, name).unwrap();
        let b = k.spec.meta_usize("b").unwrap();
        let kk = k.spec.meta_usize("k").unwrap();
        let inputs = k.random_inputs(3, 0.02).unwrap();
        let out = k.run(&inputs).unwrap()[0].to_vec::<f32>().unwrap();
        assert_finite(&out, name);
        let q = inputs[0].to_vec::<f32>().unwrap();
        let p = peft::packed_dim(b);
        // check the first 4 blocks against the host CNP
        for blk in 0..4 {
            let r = peft::cayley_neumann(&q[blk * p..(blk + 1) * p], b, kk).unwrap();
            let got = &out[blk * b * b..(blk + 1) * b * b];
            let diff = max_abs_diff(got, &r.data);
            assert!(diff < 1e-4, "{name} block {blk}: diff {diff}");
        }
    }
}

#[test]
fn cnp_kernel_is_orthogonal_for_small_q() {
    let e = engine();
    let cat = catalog();
    let k = cat.compile(&e, "cnp_b32_k8").unwrap();
    let inputs = k.random_inputs(5, 0.01).unwrap();
    let out = k.run(&inputs).unwrap()[0].to_vec::<f32>().unwrap();
    let b = 32;
    for blk in 0..3 {
        let r = Tensor::from_vec(&[b, b], out[blk * b * b..(blk + 1) * b * b].to_vec());
        let err = peft::orthogonality_error(&r);
        assert!(err < 1e-3, "block {blk}: orthogonality error {err}");
    }
}

#[test]
fn neumann_error_decreases_with_k() {
    let e = engine();
    let cat = catalog();
    let b = 32;
    let p = peft::packed_dim(b);
    let mut rng = Rng::new(9);
    let packed: Vec<f32> = rng.normal_vec(32 * p, 0.02);
    let mut errs = Vec::new();
    for k in [1usize, 3, 6, 8] {
        let kern = cat.compile(&e, &format!("cnp_b{b}_k{k}")).unwrap();
        let out = kern.run(&[lit_f32(&[32, p], &packed).unwrap()]).unwrap()[0]
            .to_vec::<f32>()
            .unwrap();
        // compare block 0 against the exact Cayley
        let exact = peft::cayley_exact(&packed[..p], b).unwrap();
        errs.push(max_abs_diff(&out[..b * b], &exact.data));
    }
    for w in errs.windows(2) {
        assert!(w[1] <= w[0] * 1.5 + 1e-7, "errors not decreasing: {errs:?}");
    }
    assert!(errs.last().unwrap() < &1e-4, "k=8 error too large: {errs:?}");
}

#[test]
fn cnp_beats_schulz_inverse_on_accuracy_budget() {
    // Both parameterizations approximate the exact Cayley transform;
    // in the small-||Q|| finetuning regime each should be accurate.
    let e = engine();
    let cat = catalog();
    let b = 16;
    let p = peft::packed_dim(b);
    let mut rng = Rng::new(13);
    let packed: Vec<f32> = rng.normal_vec(32 * p, 0.02);
    let input = lit_f32(&[32, p], &packed).unwrap();
    let cnp = cat.compile(&e, "cnp_b16").unwrap();
    let schulz = cat.compile(&e, "cayley_schulz_b16").unwrap();
    let a = cnp.run(std::slice::from_ref(&input)).unwrap()[0]
        .to_vec::<f32>()
        .unwrap();
    let s = schulz.run(std::slice::from_ref(&input)).unwrap()[0]
        .to_vec::<f32>()
        .unwrap();
    let exact = peft::cayley_exact(&packed[..p], b).unwrap();
    assert!(max_abs_diff(&a[..b * b], &exact.data) < 1e-3);
    assert!(max_abs_diff(&s[..b * b], &exact.data) < 1e-4);
}

#[test]
fn rotate_kernel_matches_host_oracle() {
    // The engine's fused CNP+rotate kernel vs the naive peft oracle.
    let e = engine();
    let cat = catalog();
    let k = cat.compile(&e, "rotate_d256").unwrap();
    // realistic adapter regime: small Q (the paper's ||Q|| < 1 setting)
    let inputs = k.random_inputs(7, 0.05).unwrap();
    let out = k.run(&inputs).unwrap()[0].to_vec::<f32>().unwrap();
    assert_finite(&out, "rotate_d256");

    let rows = 128;
    let d = 256;
    let b = 32; // MICRO_B
    let p = peft::packed_dim(b);
    let x = Tensor::from_vec(&[rows, d], inputs[0].to_vec::<f32>().unwrap());
    let q = inputs[1].to_vec::<f32>().unwrap();
    let blocks: Vec<Tensor> = (0..d / b)
        .map(|i| peft::cayley_neumann(&q[i * p..(i + 1) * p], b, 5).unwrap())
        .collect();
    let want = peft::block_rotate(&x, &blocks).unwrap();
    let diff = max_abs_diff(&out, &want.data);
    assert!(diff < 1e-3, "rotate mismatch: {diff}");
}

#[test]
fn rotate_with_zero_q_is_identity() {
    let e = engine();
    let cat = catalog();
    let k = cat.compile(&e, "rotate_d256").unwrap();
    let mut rng = Rng::new(5);
    let x: Vec<f32> = rng.normal_vec(128 * 256, 1.0);
    let q = vec![0.0f32; 8 * peft::packed_dim(32)];
    let out = k
        .run(&[
            lit_f32(&[128, 256], &x).unwrap(),
            lit_f32(&[8, 496], &q).unwrap(),
        ])
        .unwrap()[0]
        .to_vec::<f32>()
        .unwrap();
    assert!(max_abs_diff(&out, &x) < 1e-5, "R(0) != I");
}

#[test]
fn nf4_dequant_kernel_matches_rust_packing() {
    let e = engine();
    let cat = catalog();
    let k = cat.compile(&e, "nf4_dequant_1m").unwrap();
    // quantize a real tensor with the Rust packer, feed the packs
    let mut rng = Rng::new(13);
    let n = 1024 * 1024;
    let t = Tensor::randn(&[n], 0.1, &mut rng);
    let q = Nf4Tensor::quantize(&t);
    let out = k
        .run(&[
            oftv2::runtime::lit_u8(&[q.codes.len()], &q.codes).unwrap(),
            oftv2::runtime::lit_i8(&[q.absmax_q.len()], &q.absmax_q).unwrap(),
            lit_f32(&[q.absmax_s.len()], &q.absmax_s).unwrap(),
            lit_f32(&[1], &[q.offset]).unwrap(),
        ])
        .unwrap()[0]
        .to_vec::<f32>()
        .unwrap();
    let host = q.dequantize();
    let diff = max_abs_diff(&out[..n], &host.data);
    assert!(diff < 1e-5, "nf4 dequant kernel vs rust packer: {diff}");
    // and the roundtrip error is bounded like a 4-bit code should be
    let rms: f32 = t
        .data
        .iter()
        .zip(&out[..n])
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f32>()
        .sqrt()
        / (n as f32).sqrt();
    assert!(rms < 0.01, "nf4 roundtrip rms {rms}");
}

#[test]
fn awq_dequant_kernel_matches_rust_packing() {
    let e = engine();
    let cat = catalog();
    let k = cat.compile(&e, "awq_dequant_1m").unwrap();
    let mut rng = Rng::new(17);
    let (din, dout) = (1024, 1024);
    let w = Tensor::randn(&[din, dout], 0.1, &mut rng);
    let act: Vec<f32> = (0..din).map(|i| 1.0 + (i % 7) as f32).collect();
    let q = AwqTensor::quantize(&w, Some(&act)).unwrap();
    let out = k
        .run(&[
            oftv2::runtime::lit_u8(&[din / 2, dout], &q.codes).unwrap(),
            lit_f32(&[din / 64, dout], &q.scales).unwrap(),
            lit_f32(&[din], &q.eq).unwrap(),
        ])
        .unwrap()[0]
        .to_vec::<f32>()
        .unwrap();
    let host = q.dequantize();
    let diff = max_abs_diff(&out, &host.data);
    assert!(diff < 1e-5, "awq dequant kernel vs rust packer: {diff}");
}

#[test]
fn merge_and_rotate_paths_agree() {
    // Eq. (1) == Eq. (2) at the kernel level: the weight-centric
    // merge_w (cubic blockdiag merge) and the input-centric rotate_w
    // (matrix-free) must produce the same output.
    let e = engine();
    let cat = catalog();
    let merged = cat.compile(&e, "merge_w_d256").unwrap();
    let rotated = cat.compile(&e, "rotate_w_d256").unwrap();
    let inputs = merged.random_inputs(23, 0.1).unwrap();
    let a = merged.run(&inputs).unwrap()[0].to_vec::<f32>().unwrap();
    let b = rotated.run(&inputs).unwrap()[0].to_vec::<f32>().unwrap();
    let scale = a.iter().fold(0.0f32, |m, x| m.max(x.abs())).max(1.0);
    let diff = max_abs_diff(&a, &b) / scale;
    assert!(diff < 1e-3, "merge vs rotate relative diff {diff}");
}

// ---------------------------------------------------------------------------
// Bundle graphs
// ---------------------------------------------------------------------------

fn eval_args(man: &Manifest, st: &BundleState, tokens: &[i32], mask: &[f32]) -> Vec<oftv2::runtime::Value> {
    let (b, t) = (man.model.batch, man.model.seq_len);
    let mut args = st.trainable_literals(man).unwrap();
    args.extend(st.fixed.iter().cloned());
    args.push(lit_i32(&[b, t + 1], tokens).unwrap());
    args.push(lit_f32(&[b, t], mask).unwrap());
    args
}

#[test]
fn eval_loss_is_ln_vocab_at_init_for_every_tiny_bundle() {
    let e = engine();
    for tag in [
        "tiny_full",
        "tiny_none",
        "tiny_lora",
        "tiny_oft_merged",
        "tiny_oft_v2",
        "tiny_qlora_nf4",
        "tiny_qoft_nf4",
        "tiny_qlora_awq",
        "tiny_qoft_awq",
    ] {
        let man = manifest(tag);
        let st = BundleState::init(&man, 7, None).unwrap();
        let g = e
            .load_bundle_graph(&man, oftv2::runtime::BundleRole::EvalLoss)
            .unwrap();
        let (b, t) = (man.model.batch, man.model.seq_len);
        let mut rng = Rng::new(3);
        let tokens: Vec<i32> = (0..b * (t + 1)).map(|_| rng.below(250) as i32).collect();
        let mask = vec![1.0f32; b * t];
        let outs = g.run(&eval_args(&man, &st, &tokens, &mask)).unwrap();
        let sum_nll = outs[0].to_vec::<f32>().unwrap()[0];
        let count = outs[1].to_vec::<f32>().unwrap()[0];
        let mean = sum_nll / count;
        // an untrained model on random tokens: mean NLL ~ ln(vocab),
        // with slack for init noise and quantization error
        let lnv = (man.model.vocab as f32).ln();
        assert!(
            (mean - lnv).abs() < 1.0,
            "{tag}: mean NLL {mean} vs ln(V) {lnv}"
        );
        assert_eq!(count, (b * t) as f32, "{tag}");
    }
}

#[test]
fn adapter_bundles_match_base_loss_at_identity_init() {
    // At init (Q=0, B=0) every adapter is a no-op, so oft_v2 / lora /
    // oft_merged must produce exactly the base model's loss.
    let e = engine();
    let mut rng = Rng::new(3);
    let man0 = manifest("tiny_none");
    let (b, t) = (man0.model.batch, man0.model.seq_len);
    let tokens: Vec<i32> = (0..b * (t + 1)).map(|_| rng.below(250) as i32).collect();
    let mask = vec![1.0f32; b * t];

    let loss_of = |tag: &str| -> f32 {
        let man = manifest(tag);
        let st = BundleState::init(&man, 7, None).unwrap();
        let g = e
            .load_bundle_graph(&man, oftv2::runtime::BundleRole::EvalLoss)
            .unwrap();
        let outs = g.run(&eval_args(&man, &st, &tokens, &mask)).unwrap();
        outs[0].to_vec::<f32>().unwrap()[0] / outs[1].to_vec::<f32>().unwrap()[0]
    };

    let base = loss_of("tiny_none");
    for tag in ["tiny_lora", "tiny_oft_v2", "tiny_oft_merged"] {
        let l = loss_of(tag);
        assert!(
            (l - base).abs() < 1e-3,
            "{tag}: {l} vs base {base} — adapter not identity at init"
        );
    }
}

#[test]
fn logits_last_returns_vocab_row() {
    let e = engine();
    let man = manifest("tiny_oft_v2");
    let st = BundleState::init(&man, 7, None).unwrap();
    let g = e
        .load_bundle_graph(&man, oftv2::runtime::BundleRole::LogitsLast)
        .unwrap();
    let t = man.model.seq_len;
    let mut tokens = vec![0i32; t];
    tokens[0] = 1;
    tokens[1] = 42;
    let mut args = st.trainable_literals(&man).unwrap();
    args.extend(st.fixed.iter().cloned());
    args.push(lit_i32(&[1, t], &tokens).unwrap());
    args.push(oftv2::runtime::lit_scalar_i32(2));
    let outs = g.run(&args).unwrap();
    assert_eq!(outs.len(), 1);
    let logits = outs[0].to_vec::<f32>().unwrap();
    assert_eq!(logits.len(), man.model.vocab);
    assert_finite(&logits, "logits_last");
    // causality: changing a token *after* cur_len must not change logits
    let mut tokens2 = tokens.clone();
    tokens2[10] = 99;
    let mut args2 = st.trainable_literals(&man).unwrap();
    args2.extend(st.fixed.iter().cloned());
    args2.push(lit_i32(&[1, t], &tokens2).unwrap());
    args2.push(oftv2::runtime::lit_scalar_i32(2));
    let logits2 = g.run(&args2).unwrap()[0].to_vec::<f32>().unwrap();
    assert!(max_abs_diff(&logits, &logits2) < 1e-5, "future tokens leak");
}

#[test]
fn quantized_eval_close_to_full_precision() {
    // NF4/AWQ dequantization error should shift the eval loss only
    // slightly relative to the same weights in f32.
    let e = engine();
    let mut rng = Rng::new(3);
    let man_f = manifest("tiny_none");
    let (b, t) = (man_f.model.batch, man_f.model.seq_len);
    let tokens: Vec<i32> = (0..b * (t + 1)).map(|_| rng.below(250) as i32).collect();
    let mask = vec![1.0f32; b * t];

    let loss_of = |tag: &str| -> f32 {
        let man = manifest(tag);
        let st = BundleState::init(&man, 7, None).unwrap();
        let g = e
            .load_bundle_graph(&man, oftv2::runtime::BundleRole::EvalLoss)
            .unwrap();
        let outs = g.run(&eval_args(&man, &st, &tokens, &mask)).unwrap();
        outs[0].to_vec::<f32>().unwrap()[0] / outs[1].to_vec::<f32>().unwrap()[0]
    };
    let full = loss_of("tiny_none");
    for tag in ["tiny_qoft_nf4", "tiny_qoft_awq"] {
        let quant = loss_of(tag);
        assert!(
            (quant - full).abs() < 0.3,
            "{tag}: quantized loss {quant} too far from f32 {full}"
        );
    }
}

#[test]
fn train_step_io_contract_holds() {
    // 3n+1 outputs, finite loss, and a parameter actually moves.
    let e = engine();
    let man = manifest("tiny_oft_v2");
    let st = BundleState::init(&man, 7, None).unwrap();
    let g = e
        .load_bundle_graph(&man, oftv2::runtime::BundleRole::TrainStep)
        .unwrap();
    let (b, t) = (man.model.batch, man.model.seq_len);
    let mut rng = Rng::new(3);
    let tokens: Vec<i32> = (0..b * (t + 1)).map(|_| rng.below(250) as i32).collect();
    let mask = vec![1.0f32; b * t];
    let n = man.trainable.len();
    let mut args = st.trainable_literals(&man).unwrap();
    args.extend(st.zero_moments(&man).unwrap());
    args.extend(st.zero_moments(&man).unwrap());
    args.extend(st.fixed.iter().cloned());
    args.push(lit_i32(&[b, t + 1], &tokens).unwrap());
    args.push(lit_f32(&[b, t], &mask).unwrap());
    args.push(oftv2::runtime::lit_scalar_f32(1e-2));
    args.push(oftv2::runtime::lit_scalar_f32(1.0));
    let outs = g.run(&args).unwrap();
    assert_eq!(outs.len(), 3 * n + 1);
    let loss = outs[3 * n].to_vec::<f32>().unwrap()[0];
    assert!(loss.is_finite() && loss > 0.0);
    // at least one adapter moved away from identity
    let moved = (0..n).any(|i| {
        outs[i]
            .to_vec::<f32>()
            .unwrap()
            .iter()
            .any(|x| x.abs() > 1e-9)
    });
    assert!(moved, "no trainable parameter changed after one step");
}

// ---------------------------------------------------------------------------
// PJRT variants (AOT artifacts + a real `xla` crate required)
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
mod pjrt_graphs {
    use super::*;
    use oftv2::artifacts_root;

    fn have_artifacts() -> bool {
        artifacts_root().join("micro/manifest.json").exists()
    }

    #[test]
    fn pjrt_cnp_kernel_matches_host_oracle() {
        if !have_artifacts() {
            return;
        }
        let e = Engine::pjrt().expect("PJRT CPU client");
        let cat = MicroCatalog::load(artifacts_root()).unwrap();
        let k = cat.compile(&e, "cnp_b16").unwrap();
        let b = 16;
        let kk = k.spec.meta_usize("k").unwrap();
        let inputs = k.random_inputs(3, 0.02).unwrap();
        let out = k.run(&inputs).unwrap()[0].to_vec::<f32>().unwrap();
        let q = inputs[0].to_vec::<f32>().unwrap();
        let p = peft::packed_dim(b);
        for blk in 0..4 {
            let r = peft::cayley_neumann(&q[blk * p..(blk + 1) * p], b, kk).unwrap();
            let got = &out[blk * b * b..(blk + 1) * b * b];
            assert!(max_abs_diff(got, &r.data) < 1e-4);
        }
    }

    #[test]
    fn pjrt_eval_loss_matches_reference_engine() {
        if !have_artifacts() {
            return;
        }
        let pjrt = Engine::pjrt().expect("PJRT CPU client");
        let refe = Engine::reference();
        let man = Manifest::load(artifacts_root().join("tiny_oft_v2")).unwrap();
        let st = BundleState::init(&man, 7, None).unwrap();
        let (b, t) = (man.model.batch, man.model.seq_len);
        let mut rng = Rng::new(3);
        let tokens: Vec<i32> = (0..b * (t + 1)).map(|_| rng.below(250) as i32).collect();
        let mask = vec![1.0f32; b * t];
        let args = eval_args(&man, &st, &tokens, &mask);
        let a = pjrt
            .load_bundle_graph(&man, oftv2::runtime::BundleRole::EvalLoss)
            .unwrap()
            .run(&args)
            .unwrap();
        let r = refe
            .load_bundle_graph(&man, oftv2::runtime::BundleRole::EvalLoss)
            .unwrap()
            .run(&args)
            .unwrap();
        let la = a[0].to_vec::<f32>().unwrap()[0] / a[1].to_vec::<f32>().unwrap()[0];
        let lr = r[0].to_vec::<f32>().unwrap()[0] / r[1].to_vec::<f32>().unwrap()[0];
        assert!((la - lr).abs() < 1e-2, "pjrt {la} vs reference {lr}");
    }
}
