//! Scenario subsystem integration locks:
//!   * COFT keeps every trainable's deviation from identity inside the
//!     eps ball after EVERY optimizer step (and the bound binds — an
//!     unconstrained run leaves the ball on the same data);
//!   * COFT + module dropout are bitwise identical across 1 vs N
//!     workers and across a 2-rank group;
//!   * block_share / r resolution and regex targeting produce the SAME
//!     trainable counts through Manifest::builtin, the peft analytic
//!     counter, and the memory model;
//!   * GOFT and POFT — registered purely via adapters/{goft,poft}.rs —
//!     have FD-locked gradients and run the full lifecycle (train,
//!     eval, KV decode, checkpoint resume, serve, merge) selected by
//!     tag alone;
//!   * malformed scenario input (unknown knobs, bad regexes, range
//!     violations, unsupported knobs per method) errors name the valid
//!     options.

use std::sync::Arc;

use oftv2::adapters;
use oftv2::artifact::{self, merge_checkpoint};
use oftv2::artifacts_root;
use oftv2::config::RunCfg;
use oftv2::coordinator::{BaseModel, Manifest, Trainer};
use oftv2::memmodel::{self, Precision, TrainShape};
use oftv2::modelspec::ModelSpec;
use oftv2::peft::counting::count_scenario;
use oftv2::quant::requant::QuantKind;
use oftv2::runtime::refmodel::RefBundle;
use oftv2::runtime::{lit_f32, lit_i32, lit_scalar_f32, scalar_f32, Engine, Value};
use oftv2::scenario::frobenius;
use oftv2::serve::Server;
use oftv2::util::rng::Rng;

fn cfg(tag: &str, steps: usize) -> RunCfg {
    let mut c = RunCfg::default();
    c.tag = tag.into();
    c.steps = steps;
    c.log_every = 0;
    c.data.task = "math".into();
    c.data.documents = 200;
    c.optim.lr = 3e-3;
    c
}

fn man(tag: &str) -> Manifest {
    Manifest::load_or_builtin(artifacts_root().join(tag)).unwrap()
}

#[test]
fn coft_keeps_deviation_within_eps_after_every_step() {
    // The constrained run must sit inside the eps ball after EVERY
    // step — COFT is a per-step projection, not a final clamp. All
    // oft_q trainables start at Init::Zeros (identity rotation), so
    // the Frobenius norm of the packed parameter IS the deviation.
    let eps = 0.002f32;
    let e = Engine::cpu().unwrap();
    let coft_cfg = cfg("tiny_oft_v2+coft+eps=0.002", 0);
    let mut coft = Trainer::new(&e, &artifacts_root(), coft_cfg).unwrap();
    let mut free = Trainer::new(&e, &artifacts_root(), cfg("tiny_oft_v2", 0)).unwrap();

    let mut max_free = 0.0f32;
    for step in 0..8 {
        let batch = coft.loader.next_batch();
        coft.train_on(&batch).unwrap();
        free.train_on(&batch).unwrap();
        for (name, t) in coft.trainable_tensors().unwrap() {
            let dev = frobenius(&t.data);
            assert!(
                dev <= eps * 1.0001,
                "step {step}: '{name}' deviates {dev} > eps {eps}"
            );
        }
        for (_, t) in free.trainable_tensors().unwrap() {
            max_free = max_free.max(frobenius(&t.data));
        }
    }
    // The lock is only meaningful if the unconstrained twin actually
    // left the ball on the same batches.
    assert!(
        max_free > eps,
        "unconstrained run peaked at {max_free} <= eps {eps}; the COFT bound is vacuous here"
    );
}

#[test]
fn coft_and_dropout_are_bitwise_across_workers_and_ranks() {
    // The scenario's stochastic/constrained pieces must not depend on
    // execution layout: module dropout is a pure function of
    // (seed, step, name) and COFT projects the all-gathered state, so
    // 1 worker, 4 workers, and a 2-rank group all produce the same
    // bits.
    let tag = "tiny_oft_v2+coft+eps=0.002+dropout=0.3+dropout_seed=11";
    let steps = 6;

    let e = Engine::cpu().unwrap();
    let mut solo = Trainer::new(&e, &artifacts_root(), cfg(tag, steps)).unwrap();
    let hist = solo.train().unwrap();
    assert!(hist.steps.iter().all(|s| s.loss.is_finite()), "NaN loss");
    let oracle = solo.trainable_tensors().unwrap();

    // 1 vs 4 workers.
    let mut c = cfg(tag, steps);
    c.train.workers = 4;
    let mut four = Trainer::new(&e, &artifacts_root(), c).unwrap();
    let hist4 = four.train().unwrap();
    let l1: Vec<f64> = hist.steps.iter().map(|s| s.loss).collect();
    let l4: Vec<f64> = hist4.steps.iter().map(|s| s.loss).collect();
    assert_eq!(l1, l4, "loss trace differs under 4 workers");
    for ((na, ta), (nb, tb)) in oracle.iter().zip(&four.trainable_tensors().unwrap()) {
        assert_eq!(na, nb);
        assert_eq!(ta, tb, "trainable '{na}' differs under 4 workers");
    }

    // 1 process vs a 2-rank group.
    use oftv2::comms::RankGroup;
    let ranks = 2usize;
    let groups = RankGroup::mem_mesh(ranks, std::time::Duration::from_secs(60));
    let finals: Vec<_> = std::thread::scope(|s| {
        let handles: Vec<_> = groups
            .into_iter()
            .map(|g| {
                s.spawn(move || {
                    let e = Engine::cpu().unwrap();
                    let mut c = cfg(tag, steps);
                    c.train.ranks = ranks;
                    let mut tr = Trainer::new(&e, &artifacts_root(), c).unwrap();
                    tr.connect_ranks(Arc::new(g)).unwrap();
                    tr.train().unwrap();
                    tr.trainable_tensors().unwrap()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for (r, tensors) in finals.iter().enumerate() {
        for ((na, ta), (nb, tb)) in oracle.iter().zip(tensors) {
            assert_eq!(na, nb);
            assert_eq!(ta, tb, "rank {r}: trainable '{na}' differs from solo");
        }
    }
}

#[test]
fn block_share_and_r_resolution_lock_param_shapes() {
    // tiny: d_model = 64, d_ff = 256, block_b = 16.
    let plain = Manifest::builtin("tiny_oft_v2").unwrap();

    // block_share collapses every linear's packed factor to ONE shared
    // 16x16 block: 120 packed entries per linear, 6 linears x 2 layers.
    let shared = Manifest::builtin("tiny_oft_v2+block_share").unwrap();
    let q = shared
        .trainable
        .iter()
        .find(|s| s.name.ends_with("attn.wq.oft_q"))
        .unwrap();
    assert_eq!(q.shape, vec![1, 120], "block_share should leave one block");
    assert_eq!(shared.params_trainable, 12 * 120);
    assert!(shared.params_trainable < plain.params_trainable);

    // r picks the NUMBER of blocks; block size = din / r, so the same
    // r gives different block widths on attention (din 64 -> 16) and
    // the MLP down projection (din 256 -> 64).
    let r4 = Manifest::builtin("tiny_oft_v2+r=4").unwrap();
    let wq = r4
        .trainable
        .iter()
        .find(|s| s.name.ends_with("attn.wq.oft_q"))
        .unwrap();
    assert_eq!(wq.shape, vec![4, 120]);
    let down = r4
        .trainable
        .iter()
        .find(|s| s.name.ends_with("mlp.down.oft_q"))
        .unwrap();
    assert_eq!(down.shape, vec![4, 2016]); // 64-wide blocks: 64*63/2 packed

    // r and block are mutually exclusive spellings of the same choice.
    let err = format!(
        "{:#}",
        Manifest::builtin("tiny_oft_v2+r=4+block=8").unwrap_err()
    );
    assert!(err.contains("mutually exclusive"), "{err}");
}

#[test]
fn targeting_counts_agree_across_manifest_peft_and_memmodel() {
    // For every targeting/shape scenario the runtime bundle
    // (Manifest::builtin), the analytic counter (peft::counting), and
    // the memory model must report the SAME trainable count — this is
    // what keeps checkpoints, pricing, and serve in sync.
    let base = Manifest::builtin("tiny_oft_v2").unwrap();
    let spec = ModelSpec::from_dims("tiny", &base.model);
    let adapter = adapters::get("oft_v2").unwrap();
    for suffix in [
        "",
        "+target=attn",
        "+target=wq|wv",
        "+exclude=mlp",
        "+exclude=attn.w[oq]",
        "+block_share",
        "+r=4",
        "+target=attn+exclude=wo",
    ] {
        let tag = format!("tiny_oft_v2{suffix}");
        let m = Manifest::builtin(&tag).unwrap();
        let n = count_scenario(&spec, adapter, &base.model, &m.scenario).unwrap();
        assert_eq!(n, m.params_trainable, "'{tag}': peft count disagrees");

        let method =
            memmodel::Method::by_name("oft_v2", base.model.lora_r, base.model.block_b).unwrap();
        let mem = memmodel::finetune_memory_scenario(
            &spec,
            method,
            Precision::Bf16,
            TrainShape::default(),
            &m.scenario,
        )
        .unwrap();
        assert!(
            (mem.adapter_params - n as f64 * 4.0).abs() < 1.0,
            "'{tag}': memmodel prices {} bytes for {n} params",
            mem.adapter_params
        );
    }

    // Subset semantics: target=wq|wv adapts exactly 2 of the 6 linears
    // per layer; the other 4 fall back to the frozen base path.
    let sub = Manifest::builtin("tiny_oft_v2+target=wq|wv").unwrap();
    assert_eq!(sub.trainable.len(), 4, "2 linears x 2 layers");
    assert_eq!(sub.skipped.len(), 8, "4 linears x 2 layers skipped");
    assert!(sub.adapts("layers.0.attn.wq"));
    assert!(sub.adapts("layers.1.attn.wv"));
    assert!(!sub.adapts("layers.0.attn.wo"));
    assert!(!sub.adapts("layers.1.mlp.down"));
}

/// Run one lr=0 train step through the reference bundle: the returned
/// first Adam moment encodes the raw gradient (m0 = 0, so
/// new_m = (1 - b1) g), and slot 3n is the pre-update loss.
fn lr0_step(bu: &RefBundle, m: &Manifest, tr: &[Value], toks: &Value, mask: &Value) -> Vec<Value> {
    let n = tr.len();
    let zeros: Vec<Value> = m
        .trainable
        .iter()
        .map(|s| lit_f32(&s.shape, &vec![0.0; s.numel()]).unwrap())
        .collect();
    // realistic frozen base (norms at 1, weights ~N(0, 0.02)) so
    // gradient magnitudes are representative
    let fixed: Vec<Value> = m
        .frozen
        .iter()
        .map(|s| {
            let t = oftv2::coordinator::state::init_param(s, 99, None).unwrap();
            lit_f32(&s.shape, &t.data).unwrap()
        })
        .collect();
    let lr = lit_scalar_f32(0.0);
    let one = lit_scalar_f32(1.0);
    let mut inputs: Vec<&Value> = tr.iter().collect();
    inputs.extend(zeros.iter());
    inputs.extend(zeros.iter());
    inputs.extend(fixed.iter());
    inputs.push(toks);
    inputs.push(mask);
    inputs.push(&lr);
    inputs.push(&one);
    let out = bu.train_step(&inputs).unwrap();
    assert_eq!(out.len(), 3 * n + 1);
    out
}

#[test]
fn goft_and_poft_gradients_match_finite_differences() {
    // Both registry-added methods get the same FD lock the built-in
    // backwards carry: perturb the largest-gradient coordinate of the
    // first trainable and compare the central difference against the
    // analytic gradient recovered from the Adam moment.
    for tag in ["tiny_goft", "tiny_poft"] {
        let m = Manifest::builtin(tag).unwrap();
        let bu = RefBundle::from_manifest(&m).unwrap();
        let n = m.trainable.len();
        assert!(n > 0, "{tag}: no trainables");

        let mut rng = Rng::new(5);
        let tr: Vec<Value> = m
            .trainable
            .iter()
            .map(|s| lit_f32(&s.shape, &rng.normal_vec(s.numel(), 0.02)).unwrap())
            .collect();
        let (b, t) = (m.model.batch, m.model.seq_len);
        let mut brng = Rng::new(7);
        let toks: Vec<i32> = (0..b * (t + 1)).map(|_| brng.below(m.model.vocab) as i32).collect();
        let toks = lit_i32(&[b, t + 1], &toks).unwrap();
        let mask = lit_f32(&[b, t], &vec![1.0f32; b * t]).unwrap();

        let out = lr0_step(&bu, &m, &tr, &toks, &mask);
        let loss0 = scalar_f32(&out[3 * n]).unwrap();
        assert!(loss0.is_finite() && loss0 > 0.0, "{tag}: loss {loss0}");

        let g: Vec<f32> = out[n].to_vec::<f32>().unwrap();
        let grad: Vec<f32> = g.iter().map(|x| x / (1.0 - 0.9)).collect();
        let (best, gbest) = grad
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
            .map(|(i, g)| (i, *g))
            .unwrap();
        assert!(gbest.abs() > 0.0, "{tag}: zero gradient everywhere");

        let eps = 2e-2f32;
        let eval_at = |delta: f32| -> f32 {
            let mut tr2 = tr.clone();
            let mut data = tr2[0].to_vec::<f32>().unwrap();
            data[best] += delta;
            tr2[0] = lit_f32(&m.trainable[0].shape, &data).unwrap();
            let out = lr0_step(&bu, &m, &tr2, &toks, &mask);
            scalar_f32(&out[3 * n]).unwrap()
        };
        let fd = (eval_at(eps) - eval_at(-eps)) / (2.0 * eps);
        let rel = (fd - gbest).abs() / gbest.abs().max(1e-4);
        assert!(rel < 0.25, "{tag}: FD {fd} vs analytic {gbest} (rel {rel})");
    }
}

#[test]
fn goft_and_poft_train_eval_decode_checkpoint_end_to_end() {
    // Registered purely through adapters/{goft,poft}.rs — no core
    // dispatch edits — both methods must run the whole loop selected
    // by tag alone.
    let e = Engine::cpu().unwrap();
    for tag in ["tiny_goft", "tiny_poft"] {
        let steps = 12;
        let mut tr = Trainer::new(&e, &artifacts_root(), cfg(tag, steps)).unwrap();
        let hist = tr.train().unwrap();
        let first = hist.first_loss().unwrap();
        let tail = hist.tail_loss(3).unwrap();
        assert!(tail < first, "{tag}: loss did not decrease ({first} -> {tail})");
        assert!(hist.steps.iter().all(|s| s.loss.is_finite()), "{tag}: NaN");

        let (eval_loss, ppl) = tr.evaluate().unwrap();
        assert!(eval_loss.is_finite() && ppl.is_finite(), "{tag}");

        // KV decode locks token-for-token against the re-forward oracle.
        for prompt in [vec![1, 10, 20], vec![2], vec![1, 3, 5, 7, 9, 11]] {
            let old = tr.decode_greedy_reforward(&prompt, 12).unwrap();
            let new = tr.decode_greedy(&prompt, 12).unwrap();
            assert_eq!(old, new, "{tag}: KV decode diverged on {prompt:?}");
        }

        // Full-state checkpoint resume reproduces the next step bitwise.
        let ck = tr.checkpoint_full().unwrap();
        let mut tr2 = Trainer::with_checkpoint(&e, man(tag), cfg(tag, steps), Some(&ck)).unwrap();
        assert_eq!(tr2.step_count(), steps, "{tag}: step counter not restored");
        let batch = tr.loader.next_batch();
        let a = tr.train_on(&batch).unwrap();
        let b = tr2.train_on(&batch).unwrap();
        assert_eq!(a.to_bits(), b.to_bits(), "{tag}: resume diverged ({a} vs {b})");
    }
}

#[test]
fn goft_and_poft_serve_and_merge_over_a_shared_base() {
    // The serving + artifact legs of the lifecycle: a live adapter
    // loaded from the training checkpoint, and a QuantKind::None merge
    // round-tripped through the artifact file format, must both decode
    // exactly what the solo trainer decodes.
    let e = Engine::reference();
    let seed = 42u64; // RunCfg::default().seed, so solo trainers agree
    let base = BaseModel::for_preset(&e, "tiny", seed, None).unwrap();
    let prompts = [vec![1i32, 9, 4], vec![2, 7]];

    for (name, tag) in [("goft", "tiny_goft"), ("poft", "tiny_poft")] {
        let mut tr =
            Trainer::with_base(&e, man(tag), cfg(tag, 6), None, Arc::clone(&base)).unwrap();
        tr.train().unwrap();
        let ckpt = tr.checkpoint().unwrap();

        let art = merge_checkpoint(&man(tag), &ckpt, seed, QuantKind::None).unwrap();
        assert_eq!(&art.source_tag, tag);
        let path = std::env::temp_dir().join(format!(
            "oft_scenario_{}_{tag}.art",
            std::process::id()
        ));
        artifact::save(&path, &art).unwrap();
        let art = artifact::load(&path).unwrap();
        let _ = std::fs::remove_file(&path);

        let mut srv = Server::new(&e, Arc::clone(&base), 2);
        srv.add_adapter_init("live", man(tag), seed, Some(&ckpt)).unwrap();
        srv.add_artifact("merged", &art).unwrap();
        assert_eq!(srv.merged_adapters(), 1);

        for p in &prompts {
            let solo = tr.decode_greedy(p, 8).unwrap();
            for adapter in ["live", "merged"] {
                let id = srv.submit(adapter, p.clone(), 8).unwrap();
                let rs = srv.run_until_idle().unwrap();
                assert_eq!(rs.len(), 1);
                assert_eq!(rs[0].id, id);
                assert_eq!(
                    rs[0].tokens, solo,
                    "{name}: '{adapter}' decode diverged from solo on {p:?}"
                );
            }
        }
    }
}

#[test]
fn malformed_scenario_inputs_error_with_valid_options() {
    // Every rejection must tell the user what IS valid — knob list,
    // regex construct list, range, or the method's supported set.
    for (tag, needle) in [
        ("tiny_oft_v2+sparsity=0.5", "valid knobs"),
        ("tiny_oft_v2+coft=yes", "takes no value"),
        ("tiny_oft_v2+eps", "needs a value"),
        ("tiny_oft_v2+eps=-1", "must be > 0"),
        ("tiny_oft_v2+eps=nope", "expects a float"),
        ("tiny_oft_v2+dropout=1.5", "must be in [0, 1)"),
        ("tiny_oft_v2+r=0", "must be > 0"),
        ("tiny_oft_v2+r=4+block=8", "mutually exclusive"),
        ("tiny_oft_v2+target=w[q", "supported constructs"),
        ("tiny_oft_v2+target=zzz", "matches none"),
        ("tiny_full+coft", "does not support scenario knob 'coft'"),
        ("tiny_lora+coft", "does not support scenario knob 'coft'"),
        ("tiny_goft+block_share", "does not support scenario knob 'block_share'"),
    ] {
        let err = format!("{:#}", Manifest::builtin(tag).unwrap_err());
        assert!(err.contains(needle), "'{tag}' should mention '{needle}': {err}");
    }

    // The unsupported-knob error also names what the method DOES take.
    let err = format!("{:#}", Manifest::builtin("tiny_lora+coft").unwrap_err());
    for k in ["dropout", "target", "exclude"] {
        assert!(err.contains(k), "lora error should list '{k}': {err}");
    }
}
