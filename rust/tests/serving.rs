//! Serving integration: the BaseModel/AdapterState split, KV-cached
//! decode correctness against the full re-forward oracle, and the
//! continuous-batching serve loop — all on the reference engine with
//! builtin bundles.

use std::sync::Arc;

use oftv2::artifacts_root;
use oftv2::config::RunCfg;
use oftv2::coordinator::{BaseModel, Manifest, Trainer};
use oftv2::data::tokenizer::EOS;
use oftv2::runtime::Engine;
use oftv2::serve::{KvMode, RejectReason, ServeConfig, Server, Submission};

fn cfg(tag: &str, steps: usize) -> RunCfg {
    let mut c = RunCfg::default();
    c.tag = tag.into();
    c.steps = steps;
    c.log_every = 0;
    c.data.task = "math".into();
    c.data.documents = 200;
    c.optim.lr = 3e-3;
    c
}

fn man(tag: &str) -> Manifest {
    Manifest::load_or_builtin(artifacts_root().join(tag)).unwrap()
}

#[test]
fn base_buffers_upload_once_across_adapters() {
    let e = Engine::reference();
    let base = BaseModel::for_preset(&e, "tiny", 7, None).unwrap();
    let after_base = e.upload_count();
    assert_eq!(
        after_base as usize,
        base.n_buffers(),
        "base construction uploads each base parameter exactly once"
    );

    let mut srv = Server::new(&e, Arc::clone(&base), 4);
    // Full-precision adapter: every fixed input is a shared base buffer.
    srv.add_adapter_init("oft_v2", man("tiny_oft_v2"), 7, None).unwrap();
    assert_eq!(
        e.upload_count(),
        after_base,
        "attaching a full-precision adapter must not re-upload the base"
    );

    // Quantized adapter: NF4 packs are built and uploaded once...
    srv.add_adapter_init("qoft", man("tiny_qoft_nf4"), 7, None).unwrap();
    let after_qoft = e.upload_count();
    let nf4_packs = man("tiny_qoft_nf4").quantized.len() as u64;
    assert_eq!(
        after_qoft,
        after_base + nf4_packs,
        "first NF4 adapter uploads each pack exactly once"
    );

    // ...and every further NF4 adapter reuses them.
    srv.add_adapter_init("qlora", man("tiny_qlora_nf4"), 7, None).unwrap();
    assert_eq!(
        e.upload_count(),
        after_qoft,
        "second NF4 adapter must reuse the resident packs"
    );

    // Serving decodes through shared buffers: zero further uploads.
    for (i, name) in ["oft_v2", "qoft", "qlora", "oft_v2"].iter().enumerate() {
        srv.submit(name, vec![1, 5 + i as i32], 6).unwrap();
    }
    let responses = srv.run_until_idle().unwrap();
    assert_eq!(responses.len(), 4);
    assert_eq!(
        e.upload_count(),
        after_qoft,
        "decoding must run entirely over resident buffers"
    );
}

#[test]
fn kv_decode_matches_reforward_token_for_token() {
    // The KV-cached incremental decoder must emit exactly the ids the
    // old padded full re-forward emitted, for every *registered*
    // method (plain / LoRA / merged OFT / input-centric / butterfly /
    // Householder / quantized) — a new registration inherits this
    // token-for-token lock automatically.
    let e = Engine::cpu().unwrap();
    for tag in &oftv2::adapters::bundle_tags("tiny") {
        let mut tr = Trainer::new(&e, &artifacts_root(), cfg(tag, 6)).unwrap();
        tr.train().unwrap(); // non-trivial adapter weights
        for prompt in [vec![1, 10, 20], vec![2], vec![1, 3, 5, 7, 9, 11]] {
            let old = tr.decode_greedy_reforward(&prompt, 16).unwrap();
            let new = tr.decode_greedy(&prompt, 16).unwrap();
            assert_eq!(
                old, new,
                "{tag}: KV decode diverged from re-forward on prompt {prompt:?}"
            );
        }
    }
}

#[test]
fn kv_decode_fills_to_sequence_end() {
    // Generation bounded by seq_len: both paths stop at the same place.
    let e = Engine::cpu().unwrap();
    let mut tr = Trainer::new(&e, &artifacts_root(), cfg("tiny_oft_v2", 3)).unwrap();
    tr.train().unwrap();
    let t = tr.manifest.model.seq_len;
    let prompt: Vec<i32> = (0..(t - 3) as i32).map(|i| (i % 50) + 1).collect();
    let old = tr.decode_greedy_reforward(&prompt, 64).unwrap();
    let new = tr.decode_greedy(&prompt, 64).unwrap();
    assert_eq!(old, new);
    assert!(new.len() <= 3, "at most 3 positions remain before seq_len");
}

#[test]
fn serve_batches_across_adapters_and_reports_metrics() {
    let e = Engine::reference();
    let base = BaseModel::for_preset(&e, "tiny", 11, None).unwrap();
    let mut srv = Server::new(&e, base, 2);
    srv.add_adapter_init("a", man("tiny_oft_v2"), 11, None).unwrap();
    srv.add_adapter_init("b", man("tiny_qoft_nf4"), 11, None).unwrap();

    let n = 7usize;
    let mut ids = Vec::new();
    for r in 0..n {
        let name = if r % 2 == 0 { "a" } else { "b" };
        ids.push(srv.submit(name, vec![1, (r + 2) as i32], 5).unwrap());
    }
    assert_eq!(srv.queued(), n);
    let responses = srv.run_until_idle().unwrap();
    assert_eq!(responses.len(), n);
    assert_eq!(srv.queued(), 0);
    assert_eq!(srv.active(), 0);

    // every submitted id came back exactly once, tokens are in-vocab
    let mut seen: Vec<u64> = responses.iter().map(|r| r.id).collect();
    seen.sort_unstable();
    assert_eq!(seen, ids);
    let vocab = srv.vocab_of("a").unwrap() as i32;
    for r in &responses {
        assert!(!r.tokens.is_empty() && r.tokens.len() <= 5);
        assert!(r.tokens.iter().all(|&t| t >= 0 && t < vocab));
        assert!(r.latency_secs >= r.ttft_secs && r.ttft_secs >= 0.0);
    }

    let m = srv.metrics();
    assert_eq!(m.total_requests, n as u64);
    assert_eq!(m.per_adapter["a"].requests, 4);
    assert_eq!(m.per_adapter["b"].requests, 3);
    assert_eq!(
        m.total_tokens,
        responses.iter().map(|r| r.tokens.len() as u64).sum::<u64>()
    );
    assert_eq!(m.peak_active, 2, "continuous batching should fill max_batch");
    assert!(m.wall_secs > 0.0);
    assert!(m.tokens_per_sec() > 0.0);

    // zero-capacity requests (max_new == 0) complete immediately with
    // no tokens — the same empty result decode_greedy returns.
    let id0 = srv.submit("a", vec![1, 2], 0).unwrap();
    let r0 = srv.run_until_idle().unwrap();
    assert_eq!(r0.len(), 1);
    assert_eq!(r0[0].id, id0);
    assert!(r0[0].tokens.is_empty());
}

fn server_with(e: &Engine, base: Arc<BaseModel>, kv: KvMode, max_batch: usize) -> Server<'_> {
    let mut c = ServeConfig::new(max_batch);
    c.kv = kv;
    c.block_tokens = 4; // deliberately awkward: seq_len 48 -> 12 blocks
    Server::with_config(e, base, c)
}

/// Submit one request and drain the server; returns its response.
fn run_one(
    srv: &mut Server<'_>,
    adapter: &str,
    prompt: Vec<i32>,
    max_new: usize,
) -> oftv2::serve::Response {
    let id = srv.submit(adapter, prompt, max_new).unwrap();
    let rs = srv.run_until_idle().unwrap();
    assert_eq!(rs.len(), 1);
    assert_eq!(rs[0].id, id);
    rs[0].clone()
}

#[test]
fn paged_serving_matches_fifo_oracle_all_methods() {
    // The acceptance lock: the paged scheduler (block KV + adapter LRU
    // hot-swap) must emit token-for-token what the legacy contiguous
    // FIFO emits, and both must match the solo re-forward oracle — for
    // every registered method. Hot-swapping adapters must never touch
    // the shared base (upload_count stays flat).
    let e = Engine::reference();
    let seed = 42u64; // RunCfg::default().seed, so solo trainers agree
    let base = BaseModel::for_preset(&e, "tiny", seed, None).unwrap();
    let tags = oftv2::adapters::bundle_tags("tiny");

    let mut pcfg = ServeConfig::new(3);
    pcfg.block_tokens = 4;
    pcfg.max_resident = Some(2); // force LRU hot-swaps across 9 adapters
    let mut paged = Server::with_config(&e, Arc::clone(&base), pcfg);
    let mut contig = server_with(&e, Arc::clone(&base), KvMode::Contiguous, 3);
    for tag in &tags {
        paged.add_adapter_init(tag, man(tag), seed, None).unwrap();
        contig.add_adapter_init(tag, man(tag), seed, None).unwrap();
    }
    assert_eq!(paged.kv_mode(), KvMode::Paged);
    assert_eq!(contig.kv_mode(), KvMode::Contiguous);
    assert!(
        paged.resident_adapters() <= 2,
        "residency cap must evict idle decoders at attach time"
    );

    let prompts = [vec![1i32, 9, 4], vec![2], vec![1, 3, 5, 7]];
    let uploads_before_serving = e.upload_count();
    for tag in &tags {
        for p in &prompts {
            paged.submit(tag, p.clone(), 8).unwrap();
            contig.submit(tag, p.clone(), 8).unwrap();
        }
    }
    let pr = paged.run_until_idle().unwrap();
    let cr = contig.run_until_idle().unwrap();
    assert_eq!(pr.len(), tags.len() * prompts.len());
    assert_eq!(
        e.upload_count(),
        uploads_before_serving,
        "adapter hot-swap must rebuild from cached base buffers, never re-upload"
    );

    // Paged == contiguous, request by request.
    for r in &pr {
        let o = cr.iter().find(|c| c.id == r.id).unwrap();
        assert_eq!(
            r.tokens, o.tokens,
            "{}: paged diverged from the contiguous oracle",
            r.adapter
        );
    }
    // ...and both == the solo re-forward oracle over the same base.
    for (ti, tag) in tags.iter().enumerate() {
        let mut solo =
            Trainer::with_base(&e, man(tag), cfg(tag, 0), None, Arc::clone(&base)).unwrap();
        for (pi, p) in prompts.iter().enumerate() {
            let id = (ti * prompts.len() + pi) as u64;
            let r = pr.iter().find(|r| r.id == id).unwrap();
            assert_eq!(&r.adapter, tag);
            assert_eq!(
                r.tokens,
                solo.decode_greedy_reforward(p, 8).unwrap(),
                "{tag}: paged serving diverged from decode_greedy_reforward"
            );
        }
    }

    // Paging really happened, and the block pool stayed bounded.
    let m = paged.metrics();
    assert!(
        m.adapter_page_ins > 0 && m.adapter_evictions > 0,
        "9 adapters over a 2-decoder cap must hot-swap (page_ins={}, evictions={})",
        m.adapter_page_ins,
        m.adapter_evictions
    );
    assert!(m.peak_resident >= 2);
    assert_eq!(m.kv.in_use, 0, "all blocks returned to the free list");
    assert!(m.kv.peak_in_use > 0 && m.kv.peak_in_use <= m.kv.capacity_blocks);
    assert!(m.kv.slab_blocks <= m.kv.capacity_blocks);
    // Bounded: the slab high-water mark covers max_batch sequences, not
    // one contiguous seq_len cache per request served.
    let per_seq_blocks = 48usize.div_ceil(4);
    assert!(
        m.kv.slab_blocks <= 3 * per_seq_blocks,
        "slab grew past the max_batch working set: {} blocks",
        m.kv.slab_blocks
    );
    assert!(m.kv.total_allocs >= pr.len() as u64, "blocks were recycled across requests");
}

#[test]
fn serving_edge_cases_and_metrics_invariants_both_schedulers() {
    // Edge-case + invariant suite from the issue: max_new == 0, prompt
    // exactly seq_len, over-length prompts (truncation is *recorded*),
    // repeated run_until_idle accumulating wall_secs, and
    // total_tokens == Σ response.tokens.len() — against both the paged
    // scheduler and the legacy contiguous FIFO, for every method.
    let e = Engine::reference();
    let seed = 42u64;
    let base = BaseModel::for_preset(&e, "tiny", seed, None).unwrap();
    let seq_len = base.dims.seq_len;
    let tags = oftv2::adapters::bundle_tags("tiny");

    for kv in [KvMode::Paged, KvMode::Contiguous] {
        let mut srv = server_with(&e, Arc::clone(&base), kv, 2);
        for tag in &tags {
            srv.add_adapter_init(tag, man(tag), seed, None).unwrap();
        }
        let mut all_tokens = 0u64;
        for tag in &tags {
            // max_new == 0: completes immediately, empty, untruncated.
            let id = srv.submit(tag, vec![1, 2], 0).unwrap();
            let rs = srv.run_until_idle().unwrap();
            assert_eq!(rs.len(), 1);
            assert_eq!(rs[0].id, id);
            assert!(rs[0].tokens.is_empty(), "{tag} ({kv:?}): max_new=0 must emit nothing");
            assert_eq!(rs[0].truncated_tokens, 0);

            // Prompt exactly seq_len: no room to generate, no truncation.
            let full: Vec<i32> = (0..seq_len as i32).map(|i| (i % 50) + 1).collect();
            let rs = run_one(&mut srv, tag, full.clone(), 4);
            assert!(rs.tokens.is_empty(), "{tag} ({kv:?}): full prompt must emit nothing");
            assert_eq!(rs.prompt_len, seq_len);
            assert_eq!(rs.truncated_tokens, 0, "exactly seq_len is not a truncation");

            // Over-length prompt: dropped tokens are recorded, not silent.
            let mut over = full.clone();
            over.extend_from_slice(&[3, 3, 3]);
            let rs = run_one(&mut srv, tag, over, 4);
            assert_eq!(rs.truncated_tokens, 3, "{tag} ({kv:?}): truncation must be surfaced");
            assert_eq!(rs.prompt_len, seq_len);

            // A normal request for the totals invariant.
            let rs = run_one(&mut srv, tag, vec![1, 7, 3], 5);
            assert!(!rs.tokens.is_empty());
            all_tokens += rs.tokens.len() as u64;
        }
        let m = srv.metrics().clone();
        assert_eq!(m.total_tokens, all_tokens, "({kv:?}) total_tokens invariant");
        assert_eq!(m.total_requests, (4 * tags.len()) as u64);
        assert_eq!(m.truncated_requests, tags.len() as u64);
        assert_eq!(m.truncated_tokens, (3 * tags.len()) as u64);

        // Repeated run_until_idle calls accumulate wall_secs.
        let w1 = m.wall_secs;
        assert!(w1 > 0.0);
        srv.submit(&tags[0], vec![1, 2, 3], 4).unwrap();
        srv.run_until_idle().unwrap();
        assert!(
            srv.metrics().wall_secs > w1,
            "({kv:?}) wall_secs must accumulate across runs"
        );
    }
}

#[test]
fn eos_as_first_generated_token_stops_both_schedulers() {
    // Find a prompt whose very first greedy continuation is EOS, then
    // check both schedulers stop at exactly one token. The scan is over
    // a solo decoder sharing the same base, so whatever it finds holds
    // for the servers bitwise.
    let e = Engine::reference();
    let seed = 42u64;
    let base = BaseModel::for_preset(&e, "tiny", seed, None).unwrap();
    let tag = "tiny_oft_v2";
    let mut solo = Trainer::with_base(&e, man(tag), cfg(tag, 0), None, Arc::clone(&base)).unwrap();
    let vocab = solo.manifest.model.vocab as i32;
    let mut eos_prompt: Option<Vec<i32>> = None;
    'scan: for a in 1..vocab {
        for b in 0..vocab.min(16) {
            let p = if b == 0 { vec![a] } else { vec![a, b] };
            if solo.decode_greedy(&p, 1).unwrap() == [EOS] {
                eos_prompt = Some(p);
                break 'scan;
            }
        }
    }
    let Some(p) = eos_prompt else {
        // No prompt in the scanned range hits EOS first for this seed;
        // the property is vacuous here rather than failed.
        eprintln!("no EOS-first prompt found in scan range; skipping");
        return;
    };
    for kv in [KvMode::Paged, KvMode::Contiguous] {
        let mut srv = server_with(&e, Arc::clone(&base), kv, 2);
        srv.add_adapter_init(tag, man(tag), seed, None).unwrap();
        let r = run_one(&mut srv, tag, p.clone(), 8);
        assert_eq!(r.tokens, vec![EOS], "({kv:?}) EOS-first must stop after one token");
    }
}

#[test]
fn streamed_events_match_responses() {
    let e = Engine::reference();
    let base = BaseModel::for_preset(&e, "tiny", 7, None).unwrap();
    let mut srv = Server::new(&e, Arc::clone(&base), 2);
    srv.add_adapter_init("a", man("tiny_oft_v2"), 7, None).unwrap();
    srv.add_adapter_init("b", man("tiny_lora"), 7, None).unwrap();
    let ids = [
        srv.submit("a", vec![1, 9], 5).unwrap(),
        srv.submit("b", vec![2, 4], 5).unwrap(),
    ];
    // Drive incrementally via run_step, draining events as a streaming
    // gateway would.
    let mut events = Vec::new();
    let mut responses = Vec::new();
    while srv.queued() > 0 || srv.active() > 0 {
        responses.extend(srv.run_step().unwrap());
        events.extend(srv.take_events());
    }
    assert_eq!(responses.len(), 2);
    for id in ids {
        let r = responses.iter().find(|r| r.id == id).unwrap();
        let stream: Vec<i32> = events
            .iter()
            .filter(|ev| ev.request_id == id)
            .map(|ev| ev.token)
            .collect();
        assert_eq!(stream, r.tokens, "streamed tokens must equal the response");
        let lasts: Vec<bool> = events
            .iter()
            .filter(|ev| ev.request_id == id)
            .map(|ev| ev.last)
            .collect();
        assert_eq!(lasts.iter().filter(|&&l| l).count(), 1);
        assert_eq!(lasts.last(), Some(&true), "final event carries last=true");
        for (i, ev) in events.iter().filter(|ev| ev.request_id == id).enumerate() {
            assert_eq!(ev.index, i);
        }
    }
}

#[test]
fn residency_cap_one_serves_concurrent_adapters() {
    // Regression: with max_resident=1 and a batch mixing adapters,
    // paging in the second adapter while the first was pinned by an
    // active sequence used to pick the just-paged-in decoder as its own
    // eviction victim and panic ("just paged in"). The cap must be
    // temporarily exceeded instead.
    let e = Engine::reference();
    let seed = 42u64;
    let base = BaseModel::for_preset(&e, "tiny", seed, None).unwrap();
    let mut c = ServeConfig::new(4);
    c.max_resident = Some(1);
    let mut srv = Server::with_config(&e, Arc::clone(&base), c);
    srv.add_adapter_init("a", man("tiny_oft_v2"), seed, None).unwrap();
    srv.add_adapter_init("b", man("tiny_lora"), seed, None).unwrap();
    assert_eq!(srv.resident_adapters(), 1, "cap enforced while idle");
    for r in 0..4u64 {
        let name = if r % 2 == 0 { "a" } else { "b" };
        srv.submit(name, vec![1, (r + 2) as i32], 5).unwrap();
    }
    let rs = srv.run_until_idle().unwrap();
    assert_eq!(rs.len(), 4);
    let m = srv.metrics();
    assert!(m.adapter_page_ins > 0, "cap 1 over 2 adapters must page");
    assert!(m.peak_resident >= 2, "both adapters pinned in one batch");
}

#[test]
fn oversized_kv_need_rejected_at_submit_not_livelocked() {
    // Regression: a request whose worst-case KV need exceeds the whole
    // pool used to queue forever — run_until_idle errored but the
    // documented `while queued > 0 { run_step }` driver spun silently.
    // It is now rejected at the door with a reason.
    let e = Engine::reference();
    let base = BaseModel::for_preset(&e, "tiny", 7, None).unwrap();
    let mut c = ServeConfig::new(2);
    c.block_tokens = 4;
    c.max_kv_blocks = Some(2); // 8 tokens of KV against seq_len 48
    let mut srv = Server::with_config(&e, base, c);
    srv.add_adapter_init("a", man("tiny_oft_v2"), 7, None).unwrap();
    match srv.try_submit("a", vec![1, 2], 12) {
        // ceil((2 + 12) / 4) = 4 blocks > 2: never admittable.
        Submission::Rejected(RejectReason::KvExceedsPool {
            need_blocks: 4,
            capacity_blocks: 2,
        }) => {}
        r => panic!("expected KvExceedsPool rejection, got {r:?}"),
    }
    let err = srv.submit("a", vec![1, 2], 12).unwrap_err().to_string();
    assert!(err.contains("exceeds the pool capacity"), "got: {err}");
    // A request that fits the pool is served normally, and the
    // streaming driver pattern terminates.
    srv.submit("a", vec![1, 2], 5).unwrap(); // ceil(7/4) = 2 blocks
    let mut rs = Vec::new();
    while srv.queued() > 0 || srv.active() > 0 {
        rs.extend(srv.run_step().unwrap());
    }
    assert_eq!(rs.len(), 1);
    assert!(!rs[0].tokens.is_empty());
}

#[test]
fn serve_matches_solo_decode_over_shared_base() {
    // Batched multi-tenant scheduling must not change what any single
    // request decodes: same base, same adapter init, same prompt ->
    // token-for-token the ids a lone Trainer attached to the same
    // BaseModel produces. Also exercises full-precision + quantized
    // adapters sharing one base (the acceptance scenario).
    let e = Engine::reference();
    let seed = 42u64; // RunCfg::default().seed, so solo trainers agree
    let base = BaseModel::for_preset(&e, "tiny", seed, None).unwrap();

    let mut srv = Server::new(&e, Arc::clone(&base), 3);
    srv.add_adapter_init("v2", man("tiny_oft_v2"), seed, None).unwrap();
    srv.add_adapter_init("qoft", man("tiny_qoft_nf4"), seed, None).unwrap();
    let prompts: Vec<Vec<i32>> = vec![vec![1, 9, 4], vec![1, 30], vec![2, 2, 2], vec![1, 9, 4]];
    for p in &prompts {
        srv.submit("v2", p.clone(), 8).unwrap();
        srv.submit("qoft", p.clone(), 8).unwrap();
    }
    let responses = srv.run_until_idle().unwrap();
    assert_eq!(responses.len(), 2 * prompts.len());

    // Solo decoders attached to the SAME shared base.
    let mut solo_v2 = Trainer::with_base(
        &e,
        man("tiny_oft_v2"),
        cfg("tiny_oft_v2", 0),
        None,
        Arc::clone(&base),
    )
    .unwrap();
    let mut solo_q = Trainer::with_base(
        &e,
        man("tiny_qoft_nf4"),
        cfg("tiny_qoft_nf4", 0),
        None,
        Arc::clone(&base),
    )
    .unwrap();
    // Request ids are submit order: v2 even, qoft odd.
    for (i, p) in prompts.iter().enumerate() {
        let v2 = responses.iter().find(|r| r.id == (2 * i) as u64).unwrap();
        let q = responses.iter().find(|r| r.id == (2 * i + 1) as u64).unwrap();
        assert_eq!(v2.adapter, "v2");
        assert_eq!(q.adapter, "qoft");
        assert_eq!(v2.tokens, solo_v2.decode_greedy(p, 8).unwrap());
        assert_eq!(q.tokens, solo_q.decode_greedy(p, 8).unwrap());
    }
}
