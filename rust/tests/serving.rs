//! Serving integration: the BaseModel/AdapterState split, KV-cached
//! decode correctness against the full re-forward oracle, and the
//! continuous-batching serve loop — all on the reference engine with
//! builtin bundles.

use std::sync::Arc;

use oftv2::artifacts_root;
use oftv2::config::RunCfg;
use oftv2::coordinator::{BaseModel, Manifest, Trainer};
use oftv2::runtime::Engine;
use oftv2::serve::Server;

fn cfg(tag: &str, steps: usize) -> RunCfg {
    let mut c = RunCfg::default();
    c.tag = tag.into();
    c.steps = steps;
    c.log_every = 0;
    c.data.task = "math".into();
    c.data.documents = 200;
    c.optim.lr = 3e-3;
    c
}

fn man(tag: &str) -> Manifest {
    Manifest::load_or_builtin(artifacts_root().join(tag)).unwrap()
}

#[test]
fn base_buffers_upload_once_across_adapters() {
    let e = Engine::reference();
    let base = BaseModel::for_preset(&e, "tiny", 7, None).unwrap();
    let after_base = e.upload_count();
    assert_eq!(
        after_base as usize,
        base.n_buffers(),
        "base construction uploads each base parameter exactly once"
    );

    let mut srv = Server::new(&e, Arc::clone(&base), 4);
    // Full-precision adapter: every fixed input is a shared base buffer.
    srv.add_adapter_init("oft_v2", man("tiny_oft_v2"), 7, None).unwrap();
    assert_eq!(
        e.upload_count(),
        after_base,
        "attaching a full-precision adapter must not re-upload the base"
    );

    // Quantized adapter: NF4 packs are built and uploaded once...
    srv.add_adapter_init("qoft", man("tiny_qoft_nf4"), 7, None).unwrap();
    let after_qoft = e.upload_count();
    let nf4_packs = man("tiny_qoft_nf4").quantized.len() as u64;
    assert_eq!(
        after_qoft,
        after_base + nf4_packs,
        "first NF4 adapter uploads each pack exactly once"
    );

    // ...and every further NF4 adapter reuses them.
    srv.add_adapter_init("qlora", man("tiny_qlora_nf4"), 7, None).unwrap();
    assert_eq!(
        e.upload_count(),
        after_qoft,
        "second NF4 adapter must reuse the resident packs"
    );

    // Serving decodes through shared buffers: zero further uploads.
    for (i, name) in ["oft_v2", "qoft", "qlora", "oft_v2"].iter().enumerate() {
        srv.submit(name, vec![1, 5 + i as i32], 6).unwrap();
    }
    let responses = srv.run_until_idle().unwrap();
    assert_eq!(responses.len(), 4);
    assert_eq!(
        e.upload_count(),
        after_qoft,
        "decoding must run entirely over resident buffers"
    );
}

#[test]
fn kv_decode_matches_reforward_token_for_token() {
    // The KV-cached incremental decoder must emit exactly the ids the
    // old padded full re-forward emitted, for every *registered*
    // method (plain / LoRA / merged OFT / input-centric / butterfly /
    // Householder / quantized) — a new registration inherits this
    // token-for-token lock automatically.
    let e = Engine::cpu().unwrap();
    for tag in &oftv2::adapters::bundle_tags("tiny") {
        let mut tr = Trainer::new(&e, &artifacts_root(), cfg(tag, 6)).unwrap();
        tr.train().unwrap(); // non-trivial adapter weights
        for prompt in [vec![1, 10, 20], vec![2], vec![1, 3, 5, 7, 9, 11]] {
            let old = tr.decode_greedy_reforward(&prompt, 16).unwrap();
            let new = tr.decode_greedy(&prompt, 16).unwrap();
            assert_eq!(
                old, new,
                "{tag}: KV decode diverged from re-forward on prompt {prompt:?}"
            );
        }
    }
}

#[test]
fn kv_decode_fills_to_sequence_end() {
    // Generation bounded by seq_len: both paths stop at the same place.
    let e = Engine::cpu().unwrap();
    let mut tr = Trainer::new(&e, &artifacts_root(), cfg("tiny_oft_v2", 3)).unwrap();
    tr.train().unwrap();
    let t = tr.manifest.model.seq_len;
    let prompt: Vec<i32> = (0..(t - 3) as i32).map(|i| (i % 50) + 1).collect();
    let old = tr.decode_greedy_reforward(&prompt, 64).unwrap();
    let new = tr.decode_greedy(&prompt, 64).unwrap();
    assert_eq!(old, new);
    assert!(new.len() <= 3, "at most 3 positions remain before seq_len");
}

#[test]
fn serve_batches_across_adapters_and_reports_metrics() {
    let e = Engine::reference();
    let base = BaseModel::for_preset(&e, "tiny", 11, None).unwrap();
    let mut srv = Server::new(&e, base, 2);
    srv.add_adapter_init("a", man("tiny_oft_v2"), 11, None).unwrap();
    srv.add_adapter_init("b", man("tiny_qoft_nf4"), 11, None).unwrap();

    let n = 7usize;
    let mut ids = Vec::new();
    for r in 0..n {
        let name = if r % 2 == 0 { "a" } else { "b" };
        ids.push(srv.submit(name, vec![1, (r + 2) as i32], 5).unwrap());
    }
    assert_eq!(srv.queued(), n);
    let responses = srv.run_until_idle().unwrap();
    assert_eq!(responses.len(), n);
    assert_eq!(srv.queued(), 0);
    assert_eq!(srv.active(), 0);

    // every submitted id came back exactly once, tokens are in-vocab
    let mut seen: Vec<u64> = responses.iter().map(|r| r.id).collect();
    seen.sort_unstable();
    assert_eq!(seen, ids);
    let vocab = srv.vocab_of("a").unwrap() as i32;
    for r in &responses {
        assert!(!r.tokens.is_empty() && r.tokens.len() <= 5);
        assert!(r.tokens.iter().all(|&t| t >= 0 && t < vocab));
        assert!(r.latency_secs >= r.ttft_secs && r.ttft_secs >= 0.0);
    }

    let m = srv.metrics();
    assert_eq!(m.total_requests, n as u64);
    assert_eq!(m.per_adapter["a"].requests, 4);
    assert_eq!(m.per_adapter["b"].requests, 3);
    assert_eq!(
        m.total_tokens,
        responses.iter().map(|r| r.tokens.len() as u64).sum::<u64>()
    );
    assert_eq!(m.peak_active, 2, "continuous batching should fill max_batch");
    assert!(m.wall_secs > 0.0);
    assert!(m.tokens_per_sec() > 0.0);

    // zero-capacity requests (max_new == 0) complete immediately with
    // no tokens — the same empty result decode_greedy returns.
    let id0 = srv.submit("a", vec![1, 2], 0).unwrap();
    let r0 = srv.run_until_idle().unwrap();
    assert_eq!(r0.len(), 1);
    assert_eq!(r0[0].id, id0);
    assert!(r0[0].tokens.is_empty());
}

#[test]
fn serve_matches_solo_decode_over_shared_base() {
    // Batched multi-tenant scheduling must not change what any single
    // request decodes: same base, same adapter init, same prompt ->
    // token-for-token the ids a lone Trainer attached to the same
    // BaseModel produces. Also exercises full-precision + quantized
    // adapters sharing one base (the acceptance scenario).
    let e = Engine::reference();
    let seed = 42u64; // RunCfg::default().seed, so solo trainers agree
    let base = BaseModel::for_preset(&e, "tiny", seed, None).unwrap();

    let mut srv = Server::new(&e, Arc::clone(&base), 3);
    srv.add_adapter_init("v2", man("tiny_oft_v2"), seed, None).unwrap();
    srv.add_adapter_init("qoft", man("tiny_qoft_nf4"), seed, None).unwrap();
    let prompts: Vec<Vec<i32>> = vec![vec![1, 9, 4], vec![1, 30], vec![2, 2, 2], vec![1, 9, 4]];
    for p in &prompts {
        srv.submit("v2", p.clone(), 8).unwrap();
        srv.submit("qoft", p.clone(), 8).unwrap();
    }
    let responses = srv.run_until_idle().unwrap();
    assert_eq!(responses.len(), 2 * prompts.len());

    // Solo decoders attached to the SAME shared base.
    let mut solo_v2 = Trainer::with_base(
        &e,
        man("tiny_oft_v2"),
        cfg("tiny_oft_v2", 0),
        None,
        Arc::clone(&base),
    )
    .unwrap();
    let mut solo_q = Trainer::with_base(
        &e,
        man("tiny_qoft_nf4"),
        cfg("tiny_qoft_nf4", 0),
        None,
        Arc::clone(&base),
    )
    .unwrap();
    // Request ids are submit order: v2 even, qoft odd.
    for (i, p) in prompts.iter().enumerate() {
        let v2 = responses.iter().find(|r| r.id == (2 * i) as u64).unwrap();
        let q = responses.iter().find(|r| r.id == (2 * i + 1) as u64).unwrap();
        assert_eq!(v2.adapter, "v2");
        assert_eq!(q.adapter, "qoft");
        assert_eq!(v2.tokens, solo_v2.decode_greedy(p, 8).unwrap());
        assert_eq!(q.tokens, solo_q.decode_greedy(p, 8).unwrap());
    }
}
