//! SIMD-vs-scalar equivalence suite: every dispatched kernel against
//! its locked scalar oracle, under the documented per-kernel contract
//! (see README "Performance" and `tensor::simd`):
//!
//! * NF4/AWQ row decode, `block_rotate_grad_r`: **bitwise**.
//! * Fused quant matmuls vs dense matmul of `dequantize()`: **bitwise
//!   consistent within a build** (they share one microkernel).
//! * Dense matmul, block rotations, HOFT reflections vs the scalar
//!   loops: <= 1e-5 (FMA + lane blocking reassociate the contraction).
//! * Deterministic at every thread count and `set_thread_cap` value.
//!
//! Every test here toggles the process-global dispatch flag
//! (`force_scalar_kernels`), so they serialize on one mutex — the flag
//! must never flip mid-kernel in a concurrently running test. With the
//! `simd` feature off the dispatched path *is* the scalar path and the
//! comparisons hold trivially; under `--features simd` they are the
//! real lock.

use std::collections::BTreeMap;
use std::sync::{Mutex, MutexGuard};

use oftv2::coordinator::{BundleState, Manifest};
use oftv2::peft;
use oftv2::quant::{AwqTensor, Nf4Tensor, QuantWeight};
use oftv2::runtime::layers::linear::{
    block_rotate_fast, block_rotate_grad_r, block_rotate_transposed, build_cnp_blocks,
};
use oftv2::runtime::refmodel::{Params, RefBundle};
use oftv2::tensor::{force_scalar_kernels, set_thread_cap, simd_kernels_active, Tensor};
use oftv2::testkit;
use oftv2::util::rng::Rng;

static LOCK: Mutex<()> = Mutex::new(());

/// Serialize tests that touch the global dispatch flag. Poison recovery:
/// a failed test must not cascade into every later one.
fn serial() -> MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Run `f` with the scalar oracle forced, restoring dispatch after.
fn with_scalar<T>(f: impl FnOnce() -> T) -> T {
    let prev = force_scalar_kernels(true);
    let out = f();
    force_scalar_kernels(prev);
    out
}

fn qweight(kind: &str, din: usize, dout: usize, seed: u64) -> QuantWeight {
    let mut rng = Rng::new(seed);
    let w = Tensor::randn(&[din, dout], 0.1, &mut rng);
    match kind {
        "nf4" => QuantWeight::nf4(Nf4Tensor::quantize(&w)).unwrap(),
        "awq" => QuantWeight::awq(AwqTensor::quantize(&w, None).unwrap()).unwrap(),
        other => panic!("unknown kind {other}"),
    }
}

#[test]
fn matmul_matches_scalar_oracle_on_odd_shapes() {
    let _g = serial();
    let mut rng = Rng::new(101);
    // Odd/unaligned dims around the 8-lane / 32-tile boundaries, the
    // rows=1 matvec, and KC-straddling contraction lengths.
    for (m, k, n) in [
        (1usize, 7usize, 5usize),
        (3, 31, 33),
        (2, 64, 72),
        (5, 300, 41),
        (129, 257, 65),
        (1, 1000, 1),
    ] {
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 0.1, &mut rng);
        let got = a.matmul(&b).unwrap();
        let want = with_scalar(|| a.matmul(&b).unwrap());
        testkit::assert_allclose(&got.data, &want.data, 1e-5, 1e-5)
            .map_err(|e| format!("({m},{k},{n}): {e}"))
            .unwrap();
    }
}

#[test]
fn fused_quant_matmuls_bitwise_consistent_with_dense_in_build() {
    // The fused kernels and the dense matmul share one microkernel per
    // dispatch mode, so fused == x @ dequantize() stays *exact* under
    // SIMD too — the lock `quant_fused.rs` establishes for the default
    // build, re-asserted with the dispatch live.
    let _g = serial();
    let mut rng = Rng::new(102);
    for kind in ["nf4", "awq"] {
        for (din, dout) in [(64usize, 33usize), (192, 96), (128, 41)] {
            let qw = qweight(kind, din, dout, rng.next_u64());
            let d = qw.dequantize();
            for m in [1usize, 7] {
                let x = Tensor::randn(&[m, din], 1.0, &mut rng);
                assert_eq!(
                    qw.matmul(&x).unwrap(),
                    x.matmul(&d).unwrap(),
                    "{kind} ({din},{dout}) m={m}"
                );
                let g = Tensor::randn(&[m, dout], 1.0, &mut rng);
                assert_eq!(
                    qw.matmul_t(&g).unwrap(),
                    g.matmul(&d.transpose2()).unwrap(),
                    "{kind}^T ({din},{dout}) m={m}"
                );
            }
        }
    }
}

#[test]
fn decode_rows_dispatch_is_bitwise() {
    let _g = serial();
    for (kind, din, dout) in [("nf4", 96usize, 40usize), ("awq", 128, 48)] {
        let qw = qweight(kind, din, dout, 7 + din as u64);
        for (r0, rows) in [(0usize, din), (3, 5), (din - 1, 1)] {
            let mut got = vec![0.0f32; rows * dout];
            qw.decode_rows(r0, rows, &mut got);
            let want = with_scalar(|| {
                let mut p = vec![f32::NAN; rows * dout];
                qw.decode_rows(r0, rows, &mut p);
                p
            });
            for (i, (x, y)) in got.iter().zip(&want).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "{kind} r0={r0} rows={rows} i={i}: {x} vs {y}"
                );
            }
        }
    }
}

#[test]
fn rotate_kernels_match_scalar_oracle() {
    let _g = serial();
    let mut rng = Rng::new(103);
    for b in [4usize, 8, 16, 32] {
        for nb in [1usize, 3] {
            let d = b * nb;
            let packed = Tensor::randn(&[nb, peft::packed_dim(b)], 0.05, &mut rng);
            let blocks = build_cnp_blocks(&packed, b, 4).unwrap();
            for m in [1usize, 13] {
                let x = Tensor::randn(&[m, d], 1.0, &mut rng);
                let dz = Tensor::randn(&[m, d], 1.0, &mut rng);

                let fwd = block_rotate_fast(&x, &blocks).unwrap();
                let fwd_s = with_scalar(|| block_rotate_fast(&x, &blocks).unwrap());
                testkit::assert_allclose(&fwd.data, &fwd_s.data, 1e-5, 1e-5)
                    .map_err(|e| format!("fwd b={b} nb={nb} m={m}: {e}"))
                    .unwrap();

                let bwd = block_rotate_transposed(&dz, &blocks).unwrap();
                let bwd_s = with_scalar(|| block_rotate_transposed(&dz, &blocks).unwrap());
                testkit::assert_allclose(&bwd.data, &bwd_s.data, 1e-5, 1e-5)
                    .map_err(|e| format!("bwd b={b} nb={nb} m={m}: {e}"))
                    .unwrap();

                // grad_r stays one scalar implementation: bitwise.
                let gr = block_rotate_grad_r(&x, &dz, b);
                let gr_s = with_scalar(|| block_rotate_grad_r(&x, &dz, b));
                for (a, c) in gr.iter().zip(&gr_s) {
                    assert_eq!(a, c, "grad_r b={b} nb={nb} m={m}");
                }
            }
        }
    }
}

/// Fused-style Params for a bundle: trainables + frozen from the state,
/// quantized bases as packed `QuantWeight`s (same harness as
/// rust/tests/quant_fused.rs).
fn bundle_params(man: &Manifest, st: &BundleState) -> Params {
    let mut map: BTreeMap<String, Tensor> = BTreeMap::new();
    for (spec, t) in man.trainable.iter().zip(&st.trainable) {
        map.insert(spec.name.clone(), t.clone());
    }
    for (spec, v) in man.frozen.iter().zip(&st.fixed[..man.frozen.len()]) {
        map.insert(
            spec.name.clone(),
            Tensor::from_vec(&spec.shape, v.f32s().unwrap().to_vec()),
        );
    }
    let mut quant: BTreeMap<String, QuantWeight> = BTreeMap::new();
    for (base, w) in &st.quantized_bases {
        let qw = match man.quant.as_str() {
            "nf4" => QuantWeight::nf4(Nf4Tensor::quantize(w)).unwrap(),
            "awq" => QuantWeight::awq(AwqTensor::quantize(w, None).unwrap()).unwrap(),
            other => panic!("unexpected quant '{other}'"),
        };
        quant.insert(base.clone(), qw);
    }
    Params { map, quant }
}

#[test]
fn all_registry_methods_match_scalar_oracle_end_to_end() {
    // Every registered method's full forward + backward (loss and all
    // gradients) with SIMD dispatch vs the scalar oracle — covers the
    // rotate paths of all 9 methods, including BOFT's butterfly factors
    // and HOFT's reflections, through the real training step.
    let _g = serial();
    for tag in oftv2::adapters::bundle_tags("tiny") {
        let man = Manifest::builtin(&tag).unwrap();
        let bu = RefBundle::from_manifest(&man).unwrap();
        let st = BundleState::init(&man, 7, None).unwrap();
        let params = bundle_params(&man, &st);

        let (b, t) = (man.model.batch, man.model.seq_len);
        let mut rng = Rng::new(17);
        let tokens: Vec<i32> = (0..b * (t + 1))
            .map(|_| rng.below(man.model.vocab) as i32)
            .collect();
        let mask = vec![1.0f32; b * t];

        let (lf, gf) = bu.loss_and_grads(&params, &tokens, &mask).unwrap();
        let (ls, gs) = with_scalar(|| bu.loss_and_grads(&params, &tokens, &mask).unwrap());
        assert!(
            (lf - ls).abs() <= 1e-5 * lf.abs().max(1.0),
            "{tag}: simd loss {lf} vs scalar loss {ls}"
        );
        assert_eq!(gf.len(), gs.len(), "{tag}: gradient key sets differ");
        for (name, g) in &gf {
            let o = &gs[name];
            testkit::assert_allclose(&g.data, &o.data, 1e-4, 1e-3)
                .map_err(|e| format!("{tag} grad '{name}': {e}"))
                .unwrap();
        }
    }
}

#[test]
fn kernels_bitwise_invariant_across_thread_caps() {
    let _g = serial();
    let mut rng = Rng::new(104);
    // Above the threading threshold so caps actually change the worker
    // count; each output row is computed by one thread either way.
    let a = Tensor::randn(&[96, 300], 1.0, &mut rng);
    let b = Tensor::randn(&[300, 64], 0.1, &mut rng);
    let hw = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    set_thread_cap(1);
    let want = a.matmul(&b).unwrap();
    for cap in 2..=hw.max(2) {
        set_thread_cap(cap);
        assert_eq!(a.matmul(&b).unwrap(), want, "matmul at cap {cap}");
    }
    set_thread_cap(usize::MAX);
    assert_eq!(a.matmul(&b).unwrap(), want, "matmul at default cap");

    let packed = Tensor::randn(&[1, peft::packed_dim(32)], 0.05, &mut rng);
    let blocks = build_cnp_blocks(&packed, 32, 4).unwrap();
    let x = Tensor::randn(&[1024, 32], 1.0, &mut rng);
    set_thread_cap(1);
    let r1 = block_rotate_fast(&x, &blocks).unwrap();
    set_thread_cap(usize::MAX);
    assert_eq!(block_rotate_fast(&x, &blocks).unwrap(), r1, "rotate at default cap");

    // Same invariance with the scalar oracle forced.
    with_scalar(|| {
        set_thread_cap(1);
        let w1 = a.matmul(&b).unwrap();
        set_thread_cap(usize::MAX);
        assert_eq!(a.matmul(&b).unwrap(), w1, "scalar matmul across caps");
    });
}

#[test]
fn force_scalar_flag_roundtrip() {
    let _g = serial();
    let prev = force_scalar_kernels(true);
    assert!(!simd_kernels_active(), "forced scalar must disable dispatch");
    let was = force_scalar_kernels(false);
    assert!(was, "swap must return the previous value");
    assert_eq!(
        simd_kernels_active(),
        cfg!(feature = "simd"),
        "unforced: dispatch tracks the compiled feature"
    );
    force_scalar_kernels(prev);
}
