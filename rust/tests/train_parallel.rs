//! The layer/tape decomposition's bitwise contracts, end to end through
//! the Trainer: gradient checkpointing and data-parallel workers must
//! change speed and memory, never numbers.
//!
//! Training decomposes every batch into per-sequence microbatches
//! combined by a fixed-order tree reduction, so for every PEFT method:
//!   * `--grad-checkpoint every-k` reproduces the full-tape gradients
//!     bitwise (recompute reruns the same deterministic kernels), and
//!   * `--workers N` reproduces the single-worker loss curve, updated
//!     parameters, and Adam moments bitwise for any N.

use oftv2::artifacts_root;
use oftv2::config::RunCfg;
use oftv2::coordinator::Trainer;
use oftv2::runtime::{CheckpointPolicy, Engine};
use oftv2::tensor::Tensor;

/// One bundle per *registered* PEFT method (quantized ones on the NF4
/// backend): a newly registered method — boft and hoft included —
/// inherits these bitwise worker/checkpoint locks automatically.
fn all_method_tags() -> Vec<String> {
    oftv2::adapters::bundle_tags("tiny")
}

/// Loss trace + trainables + Adam moments after a short training run.
struct RunOutcome {
    losses: Vec<f64>,
    trainables: Vec<(String, Tensor)>,
    moments: Vec<(String, Tensor, Tensor)>,
}

fn run(tag: &str, steps: usize, workers: usize, policy: CheckpointPolicy) -> RunOutcome {
    let e = Engine::cpu().unwrap();
    let mut cfg = RunCfg::default();
    cfg.tag = tag.into();
    cfg.steps = steps;
    cfg.log_every = 0;
    cfg.data.task = "math".into();
    cfg.data.documents = 120;
    cfg.optim.lr = 3e-3;
    cfg.train.workers = workers;
    cfg.train.grad_checkpoint = policy;
    let mut tr = Trainer::new(&e, &artifacts_root(), cfg).unwrap();
    let hist = tr.train().unwrap();
    RunOutcome {
        losses: hist.steps.iter().map(|s| s.loss).collect(),
        trainables: tr.trainable_tensors().unwrap(),
        moments: tr.adam_moments().unwrap(),
    }
}

fn assert_bitwise_equal(tag: &str, what: &str, a: &RunOutcome, b: &RunOutcome) {
    // f64 equality IS the bitwise check: any differing bit in the f32
    // losses or tensors shows up as inequality here.
    assert_eq!(a.losses, b.losses, "{tag}: loss trace differs ({what})");
    assert_eq!(
        a.trainables.len(),
        b.trainables.len(),
        "{tag}: trainable count differs ({what})"
    );
    for ((na, ta), (nb, tb)) in a.trainables.iter().zip(&b.trainables) {
        assert_eq!(na, nb);
        assert_eq!(ta, tb, "{tag}: trainable '{na}' differs ({what})");
    }
    for ((na, ma, va), (nb, mb, vb)) in a.moments.iter().zip(&b.moments) {
        assert_eq!(na, nb);
        assert_eq!(ma, mb, "{tag}: adam_m '{na}' differs ({what})");
        assert_eq!(va, vb, "{tag}: adam_v '{na}' differs ({what})");
    }
}

#[test]
fn worker_count_never_changes_training_all_methods() {
    // 1 vs 4 workers, every PEFT method: bitwise-identical loss trace,
    // trained parameters, and optimizer state. (The Adam moments after
    // step 1 from m = v = 0 encode the raw gradients, so this is also
    // the bitwise gradient check.)
    for tag in &all_method_tags() {
        let solo = run(tag, 3, 1, CheckpointPolicy::None);
        let four = run(tag, 3, 4, CheckpointPolicy::None);
        assert_bitwise_equal(tag, "1 vs 4 workers", &solo, &four);
        assert!(solo.losses.iter().all(|l| l.is_finite()), "{tag}: NaN loss");
    }
}

#[test]
fn grad_checkpointing_never_changes_training_all_methods() {
    // Full tape vs every-1 and every-2 checkpointing: the recomputed
    // segments must reproduce the gradients bitwise.
    for tag in &all_method_tags() {
        let full_tape = run(tag, 3, 1, CheckpointPolicy::None);
        for k in [1usize, 2] {
            let ck = run(tag, 3, 1, CheckpointPolicy::EveryK(k));
            assert_bitwise_equal(tag, &format!("checkpoint every-{k}"), &full_tape, &ck);
        }
    }
}

#[test]
fn workers_and_checkpointing_compose() {
    // The combined configuration (the one a memory-pressed multi-core
    // run would actually use) still matches the baseline bitwise.
    for tag in ["tiny_oft_v2", "tiny_qlora_nf4"] {
        let base = run(tag, 4, 1, CheckpointPolicy::None);
        let both = run(tag, 4, 4, CheckpointPolicy::EveryK(2));
        assert_bitwise_equal(tag, "4 workers + every-2", &base, &both);
    }
}

#[test]
fn worker_counts_beyond_batch_are_safe() {
    // More workers than sequences (tiny batch = 4) must clamp, not
    // crash or change results.
    let base = run("tiny_oft_v2", 2, 1, CheckpointPolicy::None);
    let many = run("tiny_oft_v2", 2, 16, CheckpointPolicy::None);
    assert_bitwise_equal("tiny_oft_v2", "16 workers", &base, &many);
}
