//! The layer/tape decomposition's bitwise contracts, end to end through
//! the Trainer: gradient checkpointing and data-parallel workers must
//! change speed and memory, never numbers.
//!
//! Training decomposes every batch into per-sequence microbatches
//! combined by a fixed-order tree reduction, so for every PEFT method:
//!   * `--grad-checkpoint every-k` reproduces the full-tape gradients
//!     bitwise (recompute reruns the same deterministic kernels), and
//!   * `--workers N` reproduces the single-worker loss curve, updated
//!     parameters, and Adam moments bitwise for any N.

use std::sync::Arc;
use std::time::Duration;

use oftv2::artifacts_root;
use oftv2::comms::{CommsCfg, RankGroup};
use oftv2::config::RunCfg;
use oftv2::coordinator::{checkpoint, Checkpoint, Manifest, Trainer};
use oftv2::runtime::{CheckpointPolicy, Engine};
use oftv2::tensor::Tensor;

/// One bundle per *registered* PEFT method (quantized ones on the NF4
/// backend): a newly registered method — boft and hoft included —
/// inherits these bitwise worker/checkpoint locks automatically.
fn all_method_tags() -> Vec<String> {
    oftv2::adapters::bundle_tags("tiny")
}

/// Loss trace + trainables + Adam moments after a short training run.
struct RunOutcome {
    losses: Vec<f64>,
    trainables: Vec<(String, Tensor)>,
    moments: Vec<(String, Tensor, Tensor)>,
}

fn run(tag: &str, steps: usize, workers: usize, policy: CheckpointPolicy) -> RunOutcome {
    let e = Engine::cpu().unwrap();
    let mut cfg = RunCfg::default();
    cfg.tag = tag.into();
    cfg.steps = steps;
    cfg.log_every = 0;
    cfg.data.task = "math".into();
    cfg.data.documents = 120;
    cfg.optim.lr = 3e-3;
    cfg.train.workers = workers;
    cfg.train.grad_checkpoint = policy;
    let mut tr = Trainer::new(&e, &artifacts_root(), cfg).unwrap();
    let hist = tr.train().unwrap();
    RunOutcome {
        losses: hist.steps.iter().map(|s| s.loss).collect(),
        trainables: tr.trainable_tensors().unwrap(),
        moments: tr.adam_moments().unwrap(),
    }
}

fn assert_bitwise_equal(tag: &str, what: &str, a: &RunOutcome, b: &RunOutcome) {
    // f64 equality IS the bitwise check: any differing bit in the f32
    // losses or tensors shows up as inequality here.
    assert_eq!(a.losses, b.losses, "{tag}: loss trace differs ({what})");
    assert_eq!(
        a.trainables.len(),
        b.trainables.len(),
        "{tag}: trainable count differs ({what})"
    );
    for ((na, ta), (nb, tb)) in a.trainables.iter().zip(&b.trainables) {
        assert_eq!(na, nb);
        assert_eq!(ta, tb, "{tag}: trainable '{na}' differs ({what})");
    }
    for ((na, ma, va), (nb, mb, vb)) in a.moments.iter().zip(&b.moments) {
        assert_eq!(na, nb);
        assert_eq!(ma, mb, "{tag}: adam_m '{na}' differs ({what})");
        assert_eq!(va, vb, "{tag}: adam_v '{na}' differs ({what})");
    }
}

#[test]
fn worker_count_never_changes_training_all_methods() {
    // 1 vs 4 workers, every PEFT method: bitwise-identical loss trace,
    // trained parameters, and optimizer state. (The Adam moments after
    // step 1 from m = v = 0 encode the raw gradients, so this is also
    // the bitwise gradient check.)
    for tag in &all_method_tags() {
        let solo = run(tag, 3, 1, CheckpointPolicy::None);
        let four = run(tag, 3, 4, CheckpointPolicy::None);
        assert_bitwise_equal(tag, "1 vs 4 workers", &solo, &four);
        assert!(solo.losses.iter().all(|l| l.is_finite()), "{tag}: NaN loss");
    }
}

#[test]
fn grad_checkpointing_never_changes_training_all_methods() {
    // Full tape vs every-1 and every-2 checkpointing: the recomputed
    // segments must reproduce the gradients bitwise.
    for tag in &all_method_tags() {
        let full_tape = run(tag, 3, 1, CheckpointPolicy::None);
        for k in [1usize, 2] {
            let ck = run(tag, 3, 1, CheckpointPolicy::EveryK(k));
            assert_bitwise_equal(tag, &format!("checkpoint every-{k}"), &full_tape, &ck);
        }
    }
}

#[test]
fn workers_and_checkpointing_compose() {
    // The combined configuration (the one a memory-pressed multi-core
    // run would actually use) still matches the baseline bitwise.
    for tag in ["tiny_oft_v2", "tiny_qlora_nf4"] {
        let base = run(tag, 4, 1, CheckpointPolicy::None);
        let both = run(tag, 4, 4, CheckpointPolicy::EveryK(2));
        assert_bitwise_equal(tag, "4 workers + every-2", &base, &both);
    }
}

#[test]
fn worker_counts_beyond_batch_are_safe() {
    // More workers than sequences (tiny batch = 4) must clamp, not
    // crash or change results.
    let base = run("tiny_oft_v2", 2, 1, CheckpointPolicy::None);
    let many = run("tiny_oft_v2", 2, 16, CheckpointPolicy::None);
    assert_bitwise_equal("tiny_oft_v2", "16 workers", &base, &many);
}

// ---------------------------------------------------------------------------
// Multi-rank (ZeRO-1 sharded) training: same contracts, across ranks
// ---------------------------------------------------------------------------

/// One rank's run inside a connected group: train, then the collective
/// state reads — every rank must enter them in the same order, so all
/// of it lives in this one helper shared by the threaded and the
/// multi-process legs. Returns (outcome, full checkpoint, own shard).
fn run_in_group(
    group: RankGroup,
    tag: &str,
    steps: usize,
    workers: usize,
    policy: CheckpointPolicy,
) -> (RunOutcome, Checkpoint, Checkpoint) {
    let e = Engine::cpu().unwrap();
    let ranks = group.ranks();
    let mut cfg = RunCfg::default();
    cfg.tag = tag.into();
    cfg.steps = steps;
    cfg.log_every = 0;
    cfg.data.task = "math".into();
    cfg.data.documents = 120;
    cfg.optim.lr = 3e-3;
    cfg.train.workers = workers;
    cfg.train.grad_checkpoint = policy;
    cfg.train.ranks = ranks;
    let mut tr = Trainer::new(&e, &artifacts_root(), cfg).unwrap();
    tr.connect_ranks(Arc::new(group)).unwrap();
    let hist = tr.train().unwrap();
    let full = tr.checkpoint_full().unwrap();
    let shard = tr.checkpoint_shard().unwrap();
    let outcome = RunOutcome {
        losses: hist.steps.iter().map(|s| s.loss).collect(),
        trainables: tr.trainable_tensors().unwrap(),
        moments: tr.adam_moments().unwrap(),
    };
    (outcome, full, shard)
}

/// Run a whole rank group as threads over the in-memory mesh, assert
/// every rank saw identical state AND that the per-rank shard files
/// reassemble to the full checkpoint, then return rank 0's view.
fn run_ranks(
    tag: &str,
    steps: usize,
    ranks: usize,
    workers: usize,
    policy: CheckpointPolicy,
) -> (RunOutcome, Checkpoint) {
    let groups = RankGroup::mem_mesh(ranks, Duration::from_secs(60));
    let mut results: Vec<(RunOutcome, Checkpoint, Checkpoint)> = std::thread::scope(|s| {
        let handles: Vec<_> = groups
            .into_iter()
            .map(|g| s.spawn(move || run_in_group(g, tag, steps, workers, policy)))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("rank thread panicked"))
            .collect()
    });
    for r in 1..results.len() {
        assert_bitwise_equal(tag, &format!("rank {r} vs rank 0"), &results[r].0, &results[0].0);
        assert_eq!(
            results[r].1, results[0].1,
            "{tag}: full checkpoint differs on rank {r}"
        );
    }
    let man = Manifest::load_or_builtin(artifacts_root().join(tag)).unwrap();
    let parts: Vec<Checkpoint> = results.iter().map(|r| r.2.clone()).collect();
    let reassembled = checkpoint::reassemble_sharded(&man, &parts).unwrap();
    assert_eq!(
        reassembled, results[0].1,
        "{tag}: reassembled shards != full checkpoint"
    );
    let (outcome, full, _) = results.remove(0);
    (outcome, full)
}

#[test]
fn rank_sharding_never_changes_training_all_methods() {
    // 1 process vs 2 and 4 ranks, every registered PEFT method: the
    // distributed tree walks the same pairwise schedule as the local
    // one, and each rank's Adam window updates with the same float
    // expressions — so losses, trained parameters, and moments must be
    // bitwise identical at any rank count.
    for tag in &all_method_tags() {
        let man = Manifest::load_or_builtin(artifacts_root().join(tag)).unwrap();
        if man.params_trainable == 0 {
            // Nothing to shard ('none'): connecting must refuse with a
            // typed message, not hang or divide the empty space.
            let mut groups = RankGroup::mem_mesh(2, Duration::from_secs(5));
            let e = Engine::cpu().unwrap();
            let mut cfg = RunCfg::default();
            cfg.tag = tag.to_string();
            cfg.train.ranks = 2;
            let mut tr = Trainer::new(&e, &artifacts_root(), cfg).unwrap();
            let err = tr
                .connect_ranks(Arc::new(groups.remove(0)))
                .unwrap_err()
                .to_string();
            assert!(err.contains("exceeds"), "{tag}: unexpected error '{err}'");
            continue;
        }
        let solo = run(tag, 3, 1, CheckpointPolicy::None);
        for ranks in [2usize, 4] {
            let (sharded, _full) = run_ranks(tag, 3, ranks, 1, CheckpointPolicy::None);
            assert_bitwise_equal(tag, &format!("{ranks} ranks vs 1 process"), &solo, &sharded);
        }
    }
}

#[test]
fn ranks_workers_and_checkpointing_compose() {
    // The full stack at once — 2 ranks x 2 workers x every-2
    // checkpointing — still reproduces the plain single-process run
    // bitwise, on both a full-precision and a quantized-base method.
    for tag in ["tiny_oft_v2", "tiny_qoft_nf4"] {
        let base = run(tag, 4, 1, CheckpointPolicy::None);
        let (combo, _) = run_ranks(tag, 4, 2, 2, CheckpointPolicy::EveryK(2));
        assert_bitwise_equal(tag, "2 ranks + 2 workers + every-2", &base, &combo);
    }
}

#[test]
fn rank_counts_beyond_batch_are_safe() {
    // More ranks than sequences (tiny batch = 4): the reduction tree
    // hands the high ranks empty leaf windows and the result must not
    // move.
    let base = run("tiny_oft_v2", 2, 1, CheckpointPolicy::None);
    let (many, _) = run_ranks("tiny_oft_v2", 2, 6, 1, CheckpointPolicy::None);
    assert_bitwise_equal("tiny_oft_v2", "6 ranks", &base, &many);
}

#[test]
fn multi_process_ranks_match_single_process() {
    // Child mode: the parent below re-execs this test binary with the
    // rendezvous in env vars; the child joins over real localhost TCP,
    // runs the same helper, saves its shard file, and exits.
    if let Ok(rank) = std::env::var("OFT_TEST_RANK") {
        let rank: usize = rank.parse().unwrap();
        let ranks: usize = std::env::var("OFT_TEST_RANKS").unwrap().parse().unwrap();
        let rdv = std::env::var("OFT_TEST_RDV").unwrap();
        let tag = std::env::var("OFT_TEST_TAG").unwrap();
        let ckpt = std::env::var("OFT_TEST_CKPT").unwrap();
        let group = RankGroup::tcp(rank, ranks, &rdv, CommsCfg::fast()).unwrap();
        let (_out, _full, shard) = run_in_group(group, &tag, 3, 1, CheckpointPolicy::None);
        checkpoint::save(checkpoint::shard_checkpoint_path(&ckpt, rank, ranks), &shard).unwrap();
        return;
    }

    // Parent: one real spawned process per extra rank, three methods
    // covering full-precision OFTv2, quantized QOFT, and LoRA.
    let ranks = 2usize;
    for tag in ["tiny_oft_v2", "tiny_qoft_nf4", "tiny_lora"] {
        let solo = run(tag, 3, 1, CheckpointPolicy::None);
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let ckpt = std::env::temp_dir().join(format!("oft_mp_{}_{tag}.ckpt", std::process::id()));
        let exe = std::env::current_exe().unwrap();
        let mut children = Vec::new();
        for rank in 1..ranks {
            let child = std::process::Command::new(&exe)
                .arg("multi_process_ranks_match_single_process")
                .args(["--exact", "--test-threads=1"])
                .env("OFT_TEST_RANK", rank.to_string())
                .env("OFT_TEST_RANKS", ranks.to_string())
                .env("OFT_TEST_RDV", &addr)
                .env("OFT_TEST_TAG", tag)
                .env("OFT_TEST_CKPT", &ckpt)
                .stdout(std::process::Stdio::null())
                .spawn()
                .unwrap();
            children.push((rank, child));
        }
        let group = RankGroup::tcp_leader(listener, ranks, CommsCfg::fast()).unwrap();
        let (out, full, shard0) = run_in_group(group, tag, 3, 1, CheckpointPolicy::None);
        for (rank, mut child) in children {
            let status = child.wait().unwrap();
            assert!(status.success(), "{tag}: child rank {rank} failed: {status}");
        }
        assert_bitwise_equal(tag, &format!("{ranks} processes vs 1"), &solo, &out);

        // Sharded-vs-full checkpoint equivalence across the process
        // boundary: rank 0's in-memory shard + the children's files.
        let man = Manifest::load_or_builtin(artifacts_root().join(tag)).unwrap();
        let mut parts = vec![shard0];
        for rank in 1..ranks {
            let path = checkpoint::shard_checkpoint_path(&ckpt, rank, ranks);
            parts.push(checkpoint::load(&path).unwrap());
            let _ = std::fs::remove_file(path);
        }
        let reassembled = checkpoint::reassemble_sharded(&man, &parts).unwrap();
        assert_eq!(reassembled, full, "{tag}: reassembled != full across processes");
    }
}
