//! Integration: the full Trainer over tiny bundles — train loops,
//! determinism, checkpointing, the pretrain→finetune protocol, decode.
//!
//! Runs on the reference engine with builtin bundles: no artifacts, no
//! Python, no accelerator — `cargo test` exercises real training.

use oftv2::artifacts_root;
use oftv2::config::RunCfg;
use oftv2::coordinator::{Manifest, Trainer};
use oftv2::data::corpus::TaskKind;
use oftv2::data::loader::Loader;
use oftv2::runtime::Engine;

fn cfg(tag: &str, steps: usize) -> RunCfg {
    let mut c = RunCfg::default();
    c.tag = tag.into();
    c.steps = steps;
    c.log_every = 0;
    c.data.task = "math".into();
    c.data.documents = 200;
    c.optim.lr = 3e-3;
    c
}

#[test]
fn training_reduces_loss_for_every_method() {
    let e = Engine::cpu().unwrap();
    for tag in [
        "tiny_full",
        "tiny_lora",
        "tiny_oft_merged",
        "tiny_oft_v2",
        "tiny_qoft_nf4",
        "tiny_qlora_nf4",
    ] {
        let mut tr = Trainer::new(&e, &artifacts_root(), cfg(tag, 30)).unwrap();
        let hist = tr.train().unwrap();
        let first = hist.first_loss().unwrap();
        let tail = hist.tail_loss(5).unwrap();
        assert!(
            tail < first,
            "{tag}: loss did not decrease ({first} -> {tail})"
        );
        assert!(hist.steps.iter().all(|s| s.loss.is_finite()), "{tag}: NaN loss");
    }
}

#[test]
fn training_is_deterministic_in_seed() {
    let e = Engine::cpu().unwrap();
    let run = |seed: u64| -> Vec<f64> {
        let mut c = cfg("tiny_oft_v2", 8);
        c.seed = seed;
        let mut tr = Trainer::new(&e, &artifacts_root(), c).unwrap();
        tr.train().unwrap().steps.iter().map(|s| s.loss).collect()
    };
    let a = run(42);
    let b = run(42);
    assert_eq!(a, b, "same seed must reproduce the loss trace");
    let c = run(43);
    assert_ne!(a, c, "different seeds must differ");
}

#[test]
fn evaluate_matches_training_regime() {
    let e = Engine::cpu().unwrap();
    let mut tr = Trainer::new(&e, &artifacts_root(), cfg("tiny_oft_v2", 30)).unwrap();
    let (before, ppl_before) = tr.evaluate().unwrap();
    tr.train().unwrap();
    let (after, ppl_after) = tr.evaluate().unwrap();
    assert!(after < before, "eval loss should improve: {before} -> {after}");
    assert!(ppl_after < ppl_before);
    assert!(ppl_after > 1.0);
}

#[test]
fn checkpoint_roundtrip_preserves_eval() {
    let e = Engine::cpu().unwrap();
    let mut tr = Trainer::new(&e, &artifacts_root(), cfg("tiny_full", 10)).unwrap();
    tr.train().unwrap();
    let (loss_a, _) = tr.evaluate().unwrap();
    let ck = tr.checkpoint().unwrap();
    drop(tr);

    // restart from the checkpoint: eval must match exactly
    let man = Manifest::load_or_builtin(artifacts_root().join("tiny_full")).unwrap();
    let tr2 = Trainer::with_checkpoint(&e, man, cfg("tiny_full", 10), Some(&ck)).unwrap();
    let (loss_b, _) = tr2.evaluate().unwrap();
    assert!(
        (loss_a - loss_b).abs() < 1e-5,
        "checkpoint restart changed eval: {loss_a} vs {loss_b}"
    );
}

#[test]
fn pretrain_then_finetune_protocol() {
    let e = Engine::cpu().unwrap();
    // pretrain the full model on wiki style-0
    let mut pcfg = cfg("tiny_full", 40);
    pcfg.data.task = "wiki".into();
    pcfg.optim.lr = 2e-3;
    let mut pre = Trainer::new(&e, &artifacts_root(), pcfg).unwrap();
    pre.train().unwrap();
    let ck = pre.checkpoint().unwrap();
    drop(pre);

    // finetune OFTv2 from the checkpoint on the shifted corpus
    let man = Manifest::load_or_builtin(artifacts_root().join("tiny_oft_v2")).unwrap();
    let mut fcfg = cfg("tiny_oft_v2", 1);
    fcfg.data.task = "wiki".into();
    let mut warm = Trainer::with_checkpoint(&e, man.clone(), fcfg.clone(), Some(&ck)).unwrap();
    let dims = warm.manifest.model;
    warm.set_loader(Loader::new(TaskKind::Wiki, 200, 7, 1, dims.vocab, dims.batch, dims.seq_len));
    let (warm_loss, _) = warm.evaluate().unwrap();
    drop(warm);

    // the same adapter from a *random* base must start much worse
    let cold = Trainer::with_checkpoint(&e, man, fcfg, None).unwrap();
    let (cold_loss, _) = cold.evaluate().unwrap();
    assert!(
        warm_loss < cold_loss - 0.2,
        "pretrained init should beat random init: {warm_loss} vs {cold_loss}"
    );
}

#[test]
fn quantized_and_full_adapters_train_to_similar_loss() {
    // QOFT vs OFTv2: the NF4 base should not prevent adaptation (the
    // paper's "without compromising performance" claim, tiny-scale).
    let e = Engine::cpu().unwrap();
    let run = |tag: &str| -> f64 {
        let mut tr = Trainer::new(&e, &artifacts_root(), cfg(tag, 30)).unwrap();
        tr.train().unwrap();
        tr.evaluate().unwrap().0
    };
    let full = run("tiny_oft_v2");
    let quant = run("tiny_qoft_nf4");
    assert!(
        (quant - full).abs() < 0.5,
        "QOFT ({quant}) should track OFTv2 ({full})"
    );
}

#[test]
fn decode_emits_valid_token_ids() {
    let e = Engine::cpu().unwrap();
    let mut tr = Trainer::new(&e, &artifacts_root(), cfg("tiny_oft_v2", 5)).unwrap();
    tr.train().unwrap();
    let ids = tr.decode_greedy(&[1, 10, 20], 8).unwrap();
    assert!(!ids.is_empty());
    assert!(ids.iter().all(|&i| i >= 0 && (i as usize) < 256));
    // decode is deterministic
    let again = tr.decode_greedy(&[1, 10, 20], 8).unwrap();
    assert_eq!(ids, again);
}

#[test]
fn oft_merged_and_oft_v2_learn_equivalently() {
    // Weight-centric and input-centric OFT are the same mathematical
    // update (Eq. 1 vs Eq. 2); with identical seeds and data their loss
    // traces must agree closely. Two independent forward/backward code
    // paths in the reference engine cross-validate each other here.
    let e = Engine::cpu().unwrap();
    let run = |tag: &str| -> Vec<f64> {
        let mut tr = Trainer::new(&e, &artifacts_root(), cfg(tag, 10)).unwrap();
        tr.train().unwrap().steps.iter().map(|s| s.loss).collect()
    };
    let merged = run("tiny_oft_merged");
    let v2 = run("tiny_oft_v2");
    for (i, (a, b)) in merged.iter().zip(&v2).enumerate() {
        assert!(
            (a - b).abs() < 0.05 * a.abs().max(1.0),
            "step {i}: oft_merged {a} vs oft_v2 {b}"
        );
    }
}
