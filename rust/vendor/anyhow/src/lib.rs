//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crate registry, so this path dependency
//! provides the (small) API surface the workspace actually uses:
//! [`Error`], [`Result`], the [`anyhow!`]/[`bail!`]/[`ensure!`] macros,
//! and the [`Context`] extension trait. Semantics follow real anyhow:
//! `Display` prints the outermost message, `{:#}` prints the whole
//! context chain, `Debug` prints the chain as a "Caused by" list.

use std::fmt::{self, Display};

/// `Result<T, anyhow::Error>` with the same default-type-parameter shape
/// as the real crate.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-carrying error: an outermost message plus the chain of
/// causes beneath it (outermost first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The cause chain, outermost message first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The root (innermost) cause message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option` (mirrors anyhow's `Context`).
pub trait Context<T>: Sized {
    /// Attach a context message to the error case.
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;

    /// Attach a lazily evaluated context message to the error case.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into().context(context)),
        }
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into().context(f())),
        }
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any `Display` value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built from the arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!("condition failed: `{}`", stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = Err::<(), _>(io_err())
            .context("loading manifest")
            .unwrap_err();
        assert_eq!(format!("{e}"), "loading manifest");
        assert_eq!(format!("{e:#}"), "loading manifest: missing file");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn macros() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x == 0 {
                bail!("zero is not allowed");
            }
            ensure!(x != 1);
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(format!("{}", f(-1).unwrap_err()), "negative input -1");
        assert_eq!(format!("{}", f(0).unwrap_err()), "zero is not allowed");
        assert!(format!("{}", f(1).unwrap_err()).contains("x != 1"));
        let e = anyhow!("value {} bad", 7);
        assert_eq!(e.to_string(), "value 7 bad");
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        let e = v.context("nothing there").unwrap_err();
        assert_eq!(e.to_string(), "nothing there");
        assert_eq!(Some(3).context("x").unwrap(), 3);
    }

    #[test]
    fn question_mark_conversion() {
        fn f() -> Result<String> {
            let s = std::str::from_utf8(&[0xFF])?;
            Ok(s.to_string())
        }
        assert!(f().is_err());
    }

    #[test]
    fn chain_accessors() {
        let e = Error::msg("root").context("mid").context("top");
        let chain: Vec<&str> = e.chain().collect();
        assert_eq!(chain, vec!["top", "mid", "root"]);
        assert_eq!(e.root_cause(), "root");
    }
}
