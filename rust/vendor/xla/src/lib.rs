//! Compile-time stub for the `xla` PJRT crate.
//!
//! The offline build environment has neither the `xla` crate nor the
//! `xla_extension` C++ bundle, so this stub provides just enough API
//! surface for `oftv2`'s `pjrt` feature to *compile*. Every entry point
//! returns an error (or panics for infallible signatures) at runtime.
//!
//! To actually execute AOT artifacts through PJRT, point Cargo at the
//! real crate instead, e.g. in `rust/Cargo.toml`:
//!
//! ```toml
//! [patch.crates-io]
//! # xla = { git = "https://github.com/LaurentMazare/xla-rs" }
//! ```
//!
//! and build with `--features pjrt`.

use std::fmt;
use std::path::PathBuf;

/// Error type mirroring the real crate's (converts into `anyhow::Error`).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what} is unavailable — this is the in-repo xla stub; \
         patch in the real `xla` crate to use the pjrt feature"
    )))
}

/// Element types used by the oftv2 runtime.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    U8,
    S8,
}

/// Sealed-ish marker for element types the runtime moves across the
/// host boundary.
pub trait NativeType: Copy + Default {}
impl NativeType for f32 {}
impl NativeType for i32 {}
impl NativeType for u8 {}
impl NativeType for i8 {}

/// Host literal (stub).
#[derive(Debug)]
pub struct Literal(());

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _shape: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        unavailable("Literal::create_from_shape_and_untyped_data")
    }

    pub fn scalar<T: NativeType>(_v: T) -> Literal {
        panic!("xla stub: Literal::scalar is unavailable; patch in the real `xla` crate")
    }

    pub fn element_count(&self) -> usize {
        0
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        unavailable("Literal::get_first_element")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// XLA computation handle (stub).
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device-resident buffer (stub).
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _inputs: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }

    pub fn execute_b<B: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _inputs: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

/// PJRT client (stub).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _lit: &Literal,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_literal")
    }
}

/// Stub marker so `cargo build -p xla` has at least one test.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x").is_err());
    }
}
